"""Observability layer: metric primitives, traces, convergence telemetry,
exposition, and the serve-path wiring (exactly-once dispositions, span
model, Formula 8 bound, drain overrun policies)."""
import json
import urllib.request
import warnings

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.engine import apply_counts, reset_apply_counts
from repro.graph import generators
from repro.obs.convergence import (ConvergenceLog, TickTelemetry,
                                   UpdateTelemetry)
from repro.obs.export import (MetricsServer, SNAPSHOT_SCHEMA, snapshot,
                              to_prometheus, validate_snapshot)
from repro.obs.metrics import (Histogram, MetricsRegistry, NULL_REGISTRY)
from repro.obs.trace import NULL_TRACE, Trace, Tracer, profiled
from repro.serve import (GraphRegistry, PageRankService, PPRQuery,
                         ServeMetrics)


def make_service(g, **kw):
    registry = GraphRegistry()
    registry.register("g", g)
    defaults = dict(max_batch=8, cache_capacity=64, max_top_k=8)
    defaults.update(kw)
    return PageRankService(registry, **defaults)


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------

class TestMetricsPrimitives:
    def test_counter_monotone(self):
        reg = MetricsRegistry()
        c = reg.counter("t_total", "help")
        c.inc()
        c.inc(2.5)
        assert c.total() == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_set_inc_dec(self):
        reg = MetricsRegistry()
        gge = reg.gauge("t_depth", "help")
        gge.set(5)
        gge.inc(2)
        gge.dec()
        assert gge.total() == 6.0

    def test_histogram_quantiles_within_gamma_bound(self):
        """DDSketch guarantee: the reported quantile is within half a bucket
        (factor sqrt(gamma), ~1% at gamma=1.02) of the SAMPLE at the target
        rank — that sample, not an interpolated quantile, is the reference."""
        rng = np.random.default_rng(0)
        samples = rng.lognormal(mean=-6.0, sigma=2.0, size=5000)
        h = Histogram()
        for v in samples:
            h.observe(float(v))
        ordered = np.sort(samples)
        for q in (0.5, 0.9, 0.99, 0.999):
            rank = int(np.ceil(q * (len(samples) - 1) + 1))
            exact = float(ordered[rank - 1])
            approx = h.quantile(q)
            assert abs(approx - exact) / exact < 0.0101, (q, exact, approx)
        assert h.count == 5000
        assert np.isclose(h.sum, samples.sum())
        assert h.min == samples.min() and h.max == samples.max()
        np.testing.assert_allclose(h.mean, samples.mean())

    def test_histogram_zero_bucket_and_empty(self):
        h = Histogram()
        assert h.quantile(0.5) == 0.0          # empty -> 0.0
        h.observe(0.0)
        h.observe(-1e-9)                       # clock-resolution roundoff
        assert h.count == 2
        assert h.quantile(0.99) <= 0.0
        with pytest.raises(ValueError):
            h.quantile(1.5)

    def test_histogram_quantile_clamped_to_observed_range(self):
        h = Histogram()
        h.observe(1.0)
        assert h.quantile(0.0) == 1.0
        assert h.quantile(1.0) == 1.0

    def test_histogram_merge_equals_union(self):
        rng = np.random.default_rng(1)
        a, b = rng.exponential(1.0, 400), rng.exponential(5.0, 600)
        ha, hb, hu = Histogram(), Histogram(), Histogram()
        for v in a:
            ha.observe(float(v))
            hu.observe(float(v))
        for v in b:
            hb.observe(float(v))
            hu.observe(float(v))
        ha.merge(hb)
        assert ha.count == hu.count
        assert ha.quantile(0.99) == hu.quantile(0.99)
        with pytest.raises(ValueError):
            ha.merge(Histogram(gamma=1.1))

    def test_family_label_validation(self):
        reg = MetricsRegistry()
        fam = reg.counter("t_served", "help", ("graph", "disposition"))
        fam.labels(graph="g", disposition="solved").inc()
        with pytest.raises(ValueError):
            fam.labels(graph="g")              # missing label
        with pytest.raises(ValueError):
            fam.labels(graph="g", disposition="solved", extra="x")
        with pytest.raises(ValueError):
            fam.inc()                          # labeled family needs .labels
        assert fam.total() == 1.0

    def test_family_children_sorted_and_cached(self):
        reg = MetricsRegistry()
        fam = reg.counter("t_c", "", ("graph",))
        fam.labels(graph="b").inc(2)
        fam.labels(graph="a").inc(1)
        assert fam.labels(graph="b") is fam.labels(graph="b")
        assert [v for v, _ in fam.children()] == [("a",), ("b",)]

    def test_registry_idempotent_and_conflicting_registration(self):
        reg = MetricsRegistry()
        a = reg.counter("t_x", "help", ("graph",))
        assert reg.counter("t_x", "other help", ("graph",)) is a
        with pytest.raises(ValueError):
            reg.gauge("t_x", "", ("graph",))       # kind conflict
        with pytest.raises(ValueError):
            reg.counter("t_x", "", ("other",))     # label conflict

    def test_registry_reset_keeps_families(self):
        reg = MetricsRegistry()
        c = reg.counter("t_y", "")
        h = reg.histogram("t_h", "")
        c.inc(3)
        h.observe(1.0)
        reg.reset()
        assert reg.get("t_y") is c
        assert c.total() == 0.0 and h.merged().count == 0

    def test_null_registry_absorbs_everything(self):
        c = NULL_REGISTRY.counter("t_n", "", ("graph",))
        c.labels(graph="g").inc()
        c.inc()
        h = NULL_REGISTRY.histogram("t_nh", "")
        h.observe(1.0)
        assert c.total() == 0.0
        assert h.quantile(0.99) == 0.0
        assert h.percentiles() == (0.0, 0.0, 0.0)
        assert h.merged().count == 0
        assert c.children() == ()


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

class TestTrace:
    def test_span_lifecycle_and_kinds(self):
        tr = Trace("query", qid=1)
        tr.mark("submit")
        tr.begin("queue")
        assert tr.end("queue") >= 0.0
        with tr.span("solve_device", kind="device"):
            pass
        assert tr.span_names() == ["submit", "queue", "solve_device"]
        kinds = {s.name: s.kind for s in tr.spans}
        assert kinds["solve_device"] == "device"
        assert tr.end("never_begun") == 0.0     # no-op, not an error
        d = tr.as_dict()
        assert d["meta"] == {"qid": 1}
        assert all(s["duration_s"] >= 0.0 for s in d["spans"])

    def test_tracer_bounded_retention(self):
        tracer = Tracer(keep=4)
        for i in range(10):
            tr = tracer.start("query", qid=i)
            tr.mark("submit")
            tracer.finish(tr)
        assert len(tracer.finished) == 4
        assert tracer.last().meta["qid"] == 9

    def test_disabled_tracer_hands_out_null(self):
        tracer = Tracer(enabled=False)
        tr = tracer.start("query")
        assert tr is NULL_TRACE
        tr.begin("queue")
        tr.mark("submit")
        tracer.finish(tr)
        assert len(tracer.finished) == 0
        assert NULL_TRACE.spans == []           # recorded nothing

    def test_profiled_noop_without_logdir(self):
        with profiled(None):
            pass                                # must be a free no-op


# ---------------------------------------------------------------------------
# convergence telemetry
# ---------------------------------------------------------------------------

def _tick(i, used, bound, **kw):
    defaults = dict(tick=i, graph="g", engine="CooEngine", bucket=8,
                    columns=4, rounds_used=used, rounds_bound=bound,
                    residual=1e-5, converged_frac=1.0, tol=1e-4, c=0.85)
    defaults.update(kw)
    return TickTelemetry(**defaults)


class TestConvergenceLog:
    def test_totals_survive_ring_eviction(self):
        log = ConvergenceLog(keep=4)
        for i in range(20):
            log.record_tick(_tick(i, used=6, bound=12))
        assert len(log.ticks) == 4
        s = log.summary()
        assert s["ticks_recorded"] == 20
        assert s["rounds_used_total"] == 120
        assert s["rounds_saved_ratio"] == pytest.approx(0.5)
        assert s["bound_violations"] == 0

    def test_bound_violation_detected(self):
        log = ConvergenceLog()
        log.record_tick(_tick(0, used=13, bound=12))
        assert log.bound_violations == 1
        assert not log.ticks[0].within_bound

    def test_update_retention(self):
        log = ConvergenceLog()
        log.record_update(UpdateTelemetry(
            graph="g", kind="incremental", edges_changed=4, cache_dropped=1,
            cache_retained=3, duration_s=0.01))
        assert log.updates[0].retention == pytest.approx(0.75)
        assert log.summary()["cache_retention"] == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# exposition
# ---------------------------------------------------------------------------

def _sample_registry():
    reg = MetricsRegistry()
    reg.counter("t_served_total", "served", ("graph",)).labels(
        graph="mesh").inc(3)
    h = reg.histogram("t_latency_seconds", "latency", ("graph",))
    for v in (0.001, 0.002, 0.004, 0.008):
        h.labels(graph="mesh").observe(v)
    reg.gauge("t_depth", "queue depth").set(2)
    return reg


class TestExposition:
    def test_prometheus_text_format(self):
        text = to_prometheus(_sample_registry())
        assert "# TYPE t_served_total counter" in text
        assert 't_served_total{graph="mesh"} 3' in text
        assert "# TYPE t_latency_seconds histogram" in text
        assert 't_latency_seconds_count{graph="mesh"} 4' in text
        assert 'le="+Inf"} 4' in text
        assert "t_depth 2" in text
        # cumulative le counts never decrease
        cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
                if line.startswith("t_latency_seconds_bucket")]
        assert cums == sorted(cums)

    def test_snapshot_valid_and_quantiles_monotone(self):
        snap = snapshot(_sample_registry(), meta={"elapsed_s": 1.0})
        assert snap["schema"] == SNAPSHOT_SCHEMA
        assert validate_snapshot(snap) == []
        s = snap["metrics"]["t_latency_seconds"]["series"][0]
        assert s["min"] <= s["p50"] <= s["p99"] <= s["p999"] <= s["max"]
        json.dumps(snap)                        # JSON-ready end to end

    def test_validator_rejects_broken_snapshots(self):
        assert validate_snapshot([]) != []
        assert any("schema" in e for e in validate_snapshot(
            {"schema": "bogus", "metrics": {}}))
        snap = snapshot(_sample_registry())
        snap["metrics"]["t_served_total"]["series"][0]["value"] = -1
        assert any("negative counter" in e for e in validate_snapshot(snap))
        snap2 = snapshot(_sample_registry())
        snap2["metrics"]["t_latency_seconds"]["series"][0]["p99"] = 1e9
        assert any("monotone" in e for e in validate_snapshot(snap2))

    def test_validator_rejects_bound_violations(self):
        log = ConvergenceLog()
        log.record_tick(_tick(0, used=13, bound=12))
        snap = snapshot(_sample_registry(), convergence=log)
        assert any("bound_violations" in e for e in validate_snapshot(snap))

    def test_http_endpoint_serves_both_formats(self):
        server = MetricsServer(_sample_registry(), port=0).start()
        try:
            base = f"http://127.0.0.1:{server.port}"
            text = urllib.request.urlopen(f"{base}/metrics",
                                          timeout=10).read().decode()
            assert 't_served_total{graph="mesh"} 3' in text
            snap = json.loads(urllib.request.urlopen(
                f"{base}/metrics.json", timeout=10).read())
            assert validate_snapshot(snap) == []
        finally:
            server.stop()


# ---------------------------------------------------------------------------
# serve-path wiring
# ---------------------------------------------------------------------------

class TestServeInstrumentation:
    def test_single_query_traced_end_to_end(self):
        """Acceptance: one non-cached query yields the full span model,
        with the device span fenced (kind='device')."""
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(3, 7)))
        svc.run_until_drained()
        tr = svc.metrics.tracer.last("query")
        names = tr.span_names()
        for name in ("submit", "queue", "batch_form", "solve_dispatch",
                     "solve_device", "materialize"):
            assert name in names, f"missing span {name}"
        assert len(names) >= 5
        kinds = {s.name: s.kind for s in tr.spans}
        assert kinds["solve_device"] == "device"
        assert all(s.closed for s in tr.spans)
        # the trace survives into the snapshot export
        snap = svc.metrics.snapshot()
        assert any(
            {"solve_device", "materialize"} <=
            {sp["name"] for sp in t["spans"]} for t in snap["traces"])

    def test_latency_and_stage_histograms_populated(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        for i in range(4):
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i,)))
        svc.run_until_drained()
        lat = svc.metrics.latency.labels(graph="g", disposition="solved")
        assert lat.count == 4
        assert lat.quantile(0.99) >= lat.quantile(0.5) > 0.0
        for stage in ("batch_form", "solve_dispatch", "solve_device",
                      "materialize"):
            assert svc.metrics.stage.labels(stage=stage).count == 1
        assert svc.metrics.stage.labels(stage="queue").count == 4

    def test_rounds_bound_never_exceeded_adaptive(self):
        """Acceptance: Formula 8 stays a hard cap under adaptive serving."""
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, adaptive=True)
        for i in range(6):
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i, i + 11),
                                tol=1e-3))
        svc.run_until_drained()
        st_ = svc.stats
        assert st_["rounds_used"] <= st_["rounds_bound"]
        log = svc.metrics.convergence
        assert log.bound_violations == 0
        assert all(t.within_bound for t in log.ticks)
        assert all(0.0 <= t.converged_frac <= 1.0 for t in log.ticks)
        snap = svc.metrics.snapshot()
        assert validate_snapshot(snap) == []

    def test_stats_backcompat_dict(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(1,)))
        svc.run_until_drained()
        svc.submit(PPRQuery(qid=1, graph="g", seeds=(1,)))   # cache hit
        st_ = svc.stats
        for key in ("queries", "cache_hits", "solves", "solved_queries",
                    "dropped_queries", "ticks", "padded_columns", "updates",
                    "rounds_used", "rounds_bound", "noop_updates",
                    "incremental_updates", "cache_dropped", "cache_retained",
                    "refreshes"):
            assert key in st_, key
        assert st_["queries"] == 2
        assert st_["cache_hits"] == 1
        assert st_["solved_queries"] == 1

    def test_detail_false_keeps_counters_only(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, metrics=ServeMetrics(detail=False))
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(2,)))
        svc.run_until_drained()
        assert svc.stats["solved_queries"] == 1      # counters still live
        assert svc.metrics.latency.labels(
            graph="g", disposition="solved").count == 0
        assert len(svc.metrics.tracer.finished) == 0

    def test_registry_gauges_and_update_timings(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, invalidation_radius=2)
        reg = svc.metrics.registry
        assert reg.get("graph_epoch").labels(graph="g").value == 0
        # g.m counts the symmetrized directed list; the gauge publishes
        # undirected edges
        assert reg.get("graph_edges").labels(graph="g").value == g.m // 2
        # "g" was built before bind_metrics, so only post-bind builds are
        # timed: register a second graph through the live registry
        svc.registry.register("h", generators.tri_mesh(5, 6))
        assert reg.get("registry_build_seconds").labels(graph="h").count == 1
        engines = reg.get("graph_engine_info")
        live = [v for v, inst in engines.children() if inst.value == 1.0]
        assert ("g",) in [v[:1] for v in live]
        svc.update_graph("g", insert=[(0, g.n - 1)])
        assert reg.get("graph_epoch").labels(graph="g").value == 1
        upd = reg.get("registry_update_seconds")
        assert sum(inst.count for _, inst in upd.children()) == 1


class TestExactlyOnceAccounting:
    def test_cache_hit_counted_once_not_twice(self):
        """Satellite (a): a submit-time hit and its tick-time twin fill are
        each ONE disposition — cache hits+misses equals queries answered."""
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_batch=1)
        # two identical in-flight queries in different tick groups: the
        # first solves, the second is twin-filled from the cache at tick
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(5, 9)))
        svc.submit(PPRQuery(qid=1, graph="g", seeds=(5, 9)))
        results = svc.run_until_drained()
        assert results[1].cached
        # a third identical query hits synchronously at submit
        assert svc.submit(PPRQuery(qid=2, graph="g", seeds=(5, 9))) is not None
        st_ = svc.stats
        assert st_["queries"] == 3
        assert st_["cache_hits"] == 2
        assert st_["solved_queries"] == 1
        assert st_["queries"] == (st_["cache_hits"] + st_["solved_queries"]
                                  + st_["dropped_queries"])
        cs = svc.cache.stats()
        assert cs["hits"] == 2 and cs["misses"] == 1
        assert cs["hits"] + cs["misses"] == st_["queries"]

    def test_in_flight_twins_share_column_but_count_individually(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_batch=8)
        for i in range(4):                     # 4 queries, 2 distinct keys
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i % 2,)))
        results = svc.run_until_drained()
        st_ = svc.stats
        assert st_["solves"] == 1
        assert st_["solved_queries"] == 4      # every query counted
        assert results[0].batch_size == 2      # but only 2 solved columns
        assert svc.cache.stats()["misses"] == 4


class TestDrainOverrun:
    def test_overrun_raises_by_default(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_batch=1)
        for i in range(3):
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i,)))
        with pytest.raises(RuntimeError, match="did not drain"):
            svc.run_until_drained(max_ticks=1)

    def test_drain_in_exactly_max_ticks_is_not_overrun(self):
        """Regression: 3 queries at max_batch=1 drain in exactly 3 ticks —
        the boundary case must not raise."""
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_batch=1)
        for i in range(3):
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i,)))
        results = svc.run_until_drained(max_ticks=3)
        assert len(results) == 3

    def test_overrun_drop_counts_and_warns(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_batch=1)
        for i in range(3):
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i,)))
        with pytest.warns(RuntimeWarning, match="dropped 2"):
            results = svc.run_until_drained(max_ticks=1, on_overrun="drop")
        assert len(results) == 1               # only the drained query
        st_ = svc.stats
        assert st_["dropped_queries"] == 2
        assert st_["queries"] == (st_["cache_hits"] + st_["solved_queries"]
                                  + st_["dropped_queries"])
        assert svc.pending() == 0

    def test_invalid_overrun_policy_rejected(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        with pytest.raises(ValueError):
            svc.run_until_drained(on_overrun="ignore")


class TestRetraceDetector:
    def test_steady_state_ticks_do_not_retrace(self):
        """`apply_counts` counts trace-time engine applies: repeated
        same-bucket ticks must reuse the compiled solve."""
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_batch=4)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(0,)))
        svc.run_until_drained()                # compile the 1-bucket
        reset_apply_counts()
        for i in range(1, 4):
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i + 3,)))
            svc.run_until_drained()
        assert sum(apply_counts().values()) == 0, apply_counts()


# ---------------------------------------------------------------------------
# property test: disposition conservation across random interleavings
# ---------------------------------------------------------------------------

def _run_interleaving(ops, seed):
    """Drive a service through a random op sequence and check the
    conservation invariant after every step."""
    g = generators.tri_mesh(6, 7)
    svc = make_service(g, max_batch=2, cache_capacity=32,
                       invalidation_radius=2, refresh_batch=2, adaptive=True)
    rng = np.random.default_rng(seed)
    qid = 0

    def check():
        st_ = svc.stats
        disposed = (st_["cache_hits"] + st_["solved_queries"]
                    + st_["dropped_queries"])
        assert st_["queries"] == disposed + svc.pending()
        assert st_["rounds_used"] <= st_["rounds_bound"]

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for op in ops:
            if op == 0:        # submit (small seed pool -> hits + twins)
                s = (int(rng.integers(0, 6)),)
                svc.submit(PPRQuery(qid=qid, graph="g", seeds=s, tol=1e-3))
                qid += 1
            elif op == 1:      # edge update (may be a duplicate no-op)
                u, v = int(rng.integers(0, g.n)), int(rng.integers(0, g.n))
                if u != v:
                    svc.update_graph("g", insert=[(u, v)])
            elif op == 2:
                svc.tick()
            elif op == 3:
                svc.refresh_tick()
            else:              # drop-mode drain with a tiny tick budget
                svc.run_until_drained(max_ticks=1, on_overrun="drop")
            check()
        svc.run_until_drained(max_ticks=100, on_overrun="drop")
    check()
    assert svc.pending() == 0
    st_ = svc.stats
    assert st_["queries"] == (st_["cache_hits"] + st_["solved_queries"]
                              + st_["dropped_queries"])
    assert svc.metrics.convergence.bound_violations == 0


class TestDispositionConservation:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_seeded_interleavings(self, seed):
        rng = np.random.default_rng(100 + seed)
        ops = rng.integers(0, 5, size=25).tolist()
        _run_interleaving(ops, seed)

    @settings(max_examples=15, deadline=None)
    @given(ops=st.lists(st.integers(min_value=0, max_value=4),
                        min_size=1, max_size=25),
           seed=st.integers(min_value=0, max_value=2**16))
    def test_random_interleavings(self, ops, seed):
        _run_interleaving(ops, seed)
