"""Beyond-paper basis ablation: general orthogonal-series PageRank."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import cpaa, make_schedule, true_pagerank_dense
from repro.core.orthopoly import ortho_pagerank, series_coefficients
from repro.graph import generators
from repro.graph.ops import device_graph


@pytest.fixture(scope="module")
def mesh_graph():
    g = generators.tri_mesh(13, 15)
    return g, device_graph(g), true_pagerank_dense(g, 0.85)


def test_chebyshev_quadrature_matches_closed_form():
    """The general quadrature path reproduces the paper's closed form."""
    from repro.core.chebyshev import coefficient
    coeffs = series_coefficients("chebyshev", 0.85, 8)
    for k in range(9):
        want = coefficient(0.85, k) * (0.5 if k == 0 else 1.0)
        assert coeffs[k] == pytest.approx(want, rel=1e-5), k


@pytest.mark.parametrize("basis", ["chebyshev", "legendre", "chebyshev2"])
def test_all_bases_converge(mesh_graph, basis):
    g, dg, truth = mesh_graph
    pi = np.asarray(ortho_pagerank(dg, basis, 0.85, rounds=40), np.float64)
    assert np.max(np.abs(pi - truth) / truth) < 1e-4, basis


def test_every_basis_beats_monomial(mesh_graph):
    """At 12 rounds, every orthogonal basis beats the truncated geometric
    series (Forward Push) — the paper's §3 argument, generalized."""
    from repro.core import forward_push
    g, dg, truth = mesh_graph
    err_fp = np.max(np.abs(np.asarray(
        forward_push(dg, 0.85, rounds=12).pi, np.float64) - truth) / truth)
    for basis in ("chebyshev", "legendre", "chebyshev2"):
        pi = np.asarray(ortho_pagerank(dg, basis, 0.85, rounds=12), np.float64)
        err = np.max(np.abs(pi - truth) / truth)
        assert err < err_fp, (basis, err, err_fp)


def test_chebyshev_is_the_best_basis(mesh_graph):
    """The paper's choice wins: T_k gives the smallest max-rel-error at a
    fixed round budget (optimal uniform approximation)."""
    g, dg, truth = mesh_graph
    errs = {}
    for basis in ("chebyshev", "legendre", "chebyshev2"):
        pi = np.asarray(ortho_pagerank(dg, basis, 0.85, rounds=10), np.float64)
        errs[basis] = np.max(np.abs(pi - truth) / truth)
    assert errs["chebyshev"] <= min(errs.values()) * 1.001, errs
