"""Solver behaviour tests: CPAA vs direct solve, vs baselines; invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (cpaa, forward_push, make_schedule, monte_carlo, power,
                        true_pagerank_dense, err_bound)
from repro.graph import generators
from repro.graph.ops import device_graph, spmv, spmm
from repro.graph.structure import Graph


def small_graphs():
    return [
        generators.caveman(6, 10, seed=0),
        generators.tri_mesh(9, 11),
        generators.powerlaw_ba(120, 3, seed=2),
        generators.erdos_renyi(150, 8.0, seed=3),
        generators.kmer_chains(200, seed=4),
    ]


@pytest.mark.parametrize("gi", range(5))
def test_cpaa_matches_direct_solve(gi):
    g = small_graphs()[gi]
    dg = device_graph(g)
    pi_true = true_pagerank_dense(g, 0.85)
    res = cpaa(dg, c=0.85, tol=1e-8)
    err = np.max(np.abs(np.asarray(res.pi, np.float64) - pi_true) / pi_true)
    assert err < 5e-5, err


@pytest.mark.parametrize("c", [0.5, 0.85, 0.95])
def test_cpaa_matches_power(c):
    g = generators.tri_mesh(13, 17)
    dg = device_graph(g)
    a = cpaa(dg, c=c, tol=1e-9).pi
    b = power(dg, c=c, tol=1e-12, max_iter=2000).pi
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-9)


def test_cpaa_converges_faster_than_forward_push():
    """The paper's core claim: at equal round budget CPAA has smaller error."""
    g = generators.tri_mesh(11, 13)
    dg = device_graph(g)
    pi_true = true_pagerank_dense(g, 0.85)
    for rounds in (6, 9, 12):
        sched = make_schedule(0.85, rounds=rounds)
        # force exactly `rounds` iterations for both
        from repro.core.pagerank import cpaa_fixed
        pi_c, _ = cpaa_fixed(dg, jnp.asarray(sched.coeffs, jnp.float32),
                             jnp.ones((g.n,), jnp.float32), rounds=rounds)
        pi_f = forward_push(dg, 0.85, rounds=rounds).pi
        e_c = np.max(np.abs(np.asarray(pi_c, np.float64) - pi_true) / pi_true)
        e_f = np.max(np.abs(np.asarray(pi_f, np.float64) - pi_true) / pi_true)
        assert e_c < e_f, (rounds, e_c, e_f)


def test_empirical_error_within_theoretical_bound():
    """ERR_M (Formula 8) bounds the whole-graph accumulated-mass error."""
    g = generators.tri_mesh(11, 13)
    dg = device_graph(g)
    pi_true = true_pagerank_dense(g, 0.85)
    from repro.core.pagerank import cpaa_fixed
    for rounds in (8, 12, 16):
        sched = make_schedule(0.85, rounds=rounds)
        pi_c, _ = cpaa_fixed(dg, jnp.asarray(sched.coeffs, jnp.float32),
                             jnp.ones((g.n,), jnp.float32), rounds=rounds)
        # mean relative error tracks the global-mass bound; allow 2x slack for
        # structure (the paper calls the bound "very rough")
        e = np.mean(np.abs(np.asarray(pi_c, np.float64) - pi_true) / pi_true)
        assert e < 2.0 * err_bound(0.85, rounds), (rounds, e)


def test_batched_personalization_matches_columnwise():
    g = generators.powerlaw_ba(90, 3, seed=5)
    dg = device_graph(g)
    cols = jnp.stack([
        jnp.ones((g.n,), jnp.float32),
        jax.nn.one_hot(3, g.n, dtype=jnp.float32) * g.n,
        jax.nn.one_hot(41, g.n, dtype=jnp.float32) * g.n,
    ], axis=1)
    batched = cpaa(dg, 0.85, 1e-8, p=cols).pi
    for j in range(cols.shape[1]):
        single = cpaa(dg, 0.85, 1e-8, p=cols[:, j]).pi
        np.testing.assert_allclose(np.asarray(batched[:, j]), np.asarray(single),
                                   rtol=1e-5, atol=1e-9)


def test_batched_personalization_matches_singles_and_oracle():
    """The micro-batcher's bedrock: a [n, B] solve == B single-column solves
    == the dense oracle, column by column (seed-set personalizations)."""
    g = generators.tri_mesh(9, 11)
    dg = device_graph(g)
    rng = np.random.default_rng(7)
    B = 6
    p = np.zeros((g.n, B), np.float32)
    for j in range(B):
        seeds = rng.choice(g.n, rng.integers(1, 4), replace=False)
        p[seeds, j] = 1.0
    batched = np.asarray(cpaa(dg, 0.85, 1e-8, p=jnp.asarray(p)).pi)
    assert batched.shape == (g.n, B)
    oracle = np.asarray(true_pagerank_dense(g, 0.85, p=p))
    for j in range(B):
        single = np.asarray(cpaa(dg, 0.85, 1e-8, p=jnp.asarray(p[:, j])).pi)
        np.testing.assert_allclose(batched[:, j], single, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(batched[:, j], oracle[:, j],
                                   rtol=1e-4, atol=1e-7)


def test_monte_carlo_correlates_on_skewed_graph():
    g = generators.powerlaw_ba(150, 3, seed=6)
    dg = device_graph(g)
    pi_true = true_pagerank_dense(g, 0.85)
    mc = monte_carlo(dg, walks_per_node=64, max_len=80, seed=1).pi
    corr = np.corrcoef(np.asarray(mc), pi_true)[0, 1]
    assert corr > 0.97, corr


def test_monte_carlo_terminates_at_isolated_vertices():
    """Degree-0 vertices have no CSR edge range: a walk reaching one must
    terminate there instead of stepping through ANOTHER vertex's edges (the
    deg-0 offset used to land the pick inside a neighbour's slot range)."""
    base = generators.powerlaw_ba(80, 3, seed=1)   # skewed: rankable by MC
    n = base.n + 3                            # 3 isolated vertices at the end
    g = Graph.from_undirected_edges(n, base.src, base.dst,
                                    add_self_loops_to_isolated=False)
    iso = [base.n, base.n + 1, base.n + 2]
    assert all(g.deg[v] == 0 for v in iso)
    walks = 64
    res = monte_carlo(device_graph(g), walks_per_node=walks, max_len=60,
                      seed=3)
    pi = np.asarray(res.pi)
    assert np.all(np.isfinite(pi)) and pi.sum() == pytest.approx(1.0, abs=1e-5)
    # every walk that starts at an isolated vertex stops there, and no walk
    # from elsewhere can reach it: its mass is exactly walks/total
    for v in iso:
        assert pi[v] == pytest.approx(walks / (n * walks), rel=1e-6)
    # the connected part still tracks the dense oracle
    pi_true = true_pagerank_dense(base, 0.85)
    corr = np.corrcoef(pi[: base.n], pi_true)[0, 1]
    assert corr > 0.9, corr


def test_monte_carlo_edgeless_graph_is_uniform():
    g = Graph.from_undirected_edges(7, np.array([], np.int64),
                                    np.array([], np.int64),
                                    add_self_loops_to_isolated=False)
    pi = np.asarray(monte_carlo(device_graph(g)).pi)
    np.testing.assert_allclose(pi, 1.0 / 7, rtol=1e-6)


def test_default_personalization_is_unit_mass_for_all_solvers():
    """The normalization contract: every solver's default personalization is
    uniform with mass 1, so keep_history accumulators (and any intermediate
    mass readings) are directly comparable across solvers."""
    from repro.core import cpaa_adaptive
    from repro.core.pagerank import _uniform_p
    from repro.core.engine import as_engine
    g = generators.tri_mesh(9, 11)
    dg = device_graph(g)
    p = _uniform_p(as_engine(dg))
    assert float(jnp.sum(p)) == pytest.approx(1.0, rel=1e-6)
    explicit = jnp.full((g.n,), 1.0 / g.n, jnp.float32)
    for solver in (lambda **kw: cpaa(dg, 0.85, 1e-8, **kw),
                   lambda **kw: cpaa_adaptive(dg, 0.85, 1e-8, **kw),
                   lambda **kw: power(dg, 0.85, tol=1e-10, **kw),
                   lambda **kw: forward_push(dg, 0.85, rounds=40, **kw)):
        np.testing.assert_allclose(np.asarray(solver().pi),
                                   np.asarray(solver(p=explicit).pi),
                                   rtol=1e-6, atol=1e-9)
    # the history of a default solve is normalized-mass (approaches 1/(1-c)
    # before the final normalization) — pinned so solvers stay comparable
    hist = cpaa(dg, 0.85, 1e-8, keep_history=True).history
    total = float(jnp.sum(hist[-1]))
    assert total == pytest.approx(1.0 / (1.0 - 0.85), rel=1e-3)


# ---------- hypothesis property tests over random undirected graphs ----------

@st.composite
def random_graph(draw):
    n = draw(st.integers(min_value=8, max_value=60))
    n_edges = draw(st.integers(min_value=n, max_value=4 * n))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, n_edges)
    v = rng.integers(0, n, n_edges)
    return Graph.from_undirected_edges(n, u, v)


@given(random_graph())
@settings(max_examples=25, deadline=None)
def test_property_spectrum_is_real(g):
    """Lemma 2: every eigenvalue of P = A D^{-1} is real for undirected G."""
    n = g.n
    a = np.zeros((n, n)); a[g.dst, g.src] = 1.0
    p = a / np.maximum(a.sum(0), 1.0)[None, :]
    ev = np.linalg.eigvals(p)
    assert np.max(np.abs(ev.imag)) < 1e-8
    assert np.max(np.abs(ev.real)) <= 1.0 + 1e-8


@given(random_graph())
@settings(max_examples=25, deadline=None)
def test_property_mass_conservation(g):
    """e^T T_k(P) p = e^T p: total mass is invariant (paper §4.1: 'the total
    mass of the graph is constant at n')."""
    dg = device_graph(g)
    x = jnp.ones((g.n,), jnp.float32)
    t_prev, t_cur = x, spmv(dg, x)
    for _ in range(6):
        assert float(jnp.sum(t_cur)) == pytest.approx(float(jnp.sum(x)), rel=1e-4)
        t_prev, t_cur = t_cur, 2.0 * spmv(dg, t_cur) - t_prev


@given(random_graph(), st.floats(min_value=0.2, max_value=0.95))
@settings(max_examples=25, deadline=None)
def test_property_pagerank_valid_distribution(g, c):
    dg = device_graph(g)
    pi = cpaa(dg, c=c, tol=1e-7).pi
    pi = np.asarray(pi, np.float64)
    assert pi.sum() == pytest.approx(1.0, abs=1e-4)
    assert (pi > 0).all()


@given(random_graph())
@settings(max_examples=15, deadline=None)
def test_property_cpaa_equals_direct(g):
    dg = device_graph(g)
    pi = np.asarray(cpaa(dg, 0.85, 1e-8).pi, np.float64)
    pi_true = true_pagerank_dense(g, 0.85)
    assert np.max(np.abs(pi - pi_true)) < 1e-4


def test_spmv_spmm_consistency():
    g = generators.erdos_renyi(100, 6.0, seed=9)
    dg = device_graph(g)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n, 8), jnp.float32)
    ys = jnp.stack([spmv(dg, x[:, j]) for j in range(8)], axis=1)
    np.testing.assert_allclose(np.asarray(spmm(dg, x)), np.asarray(ys),
                               rtol=1e-6, atol=1e-6)
