"""Online PPR query service: batching correctness, cache, epochs, top-k."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import cpaa, true_pagerank_dense
from repro.graph import generators
from repro.graph.ops import device_graph
from repro.serve import GraphRegistry, PageRankService, PPRQuery
from repro.serve.graph_registry import _undirected_keys


def make_service(g, **kw):
    registry = GraphRegistry()
    registry.register("g", g)
    defaults = dict(max_batch=8, cache_capacity=64, max_top_k=8)
    defaults.update(kw)
    return PageRankService(registry, **defaults)


def reference_topk(g, seeds, c, tol, k):
    """Per-query cpaa (single column) + host top-k."""
    p = np.zeros(g.n, np.float32)
    p[list(seeds)] = 1.0
    pi = np.asarray(cpaa(device_graph(g), c=c, tol=tol, p=jnp.asarray(p)).pi)
    idx = np.argsort(-pi, kind="stable")[:k]
    return idx, pi[idx]


class TestMicroBatching:
    def test_batched_answers_match_per_query_solves(self):
        g = generators.tri_mesh(13, 17)
        svc = make_service(g, max_batch=8)
        rng = np.random.default_rng(0)
        queries = [PPRQuery(qid=i, graph="g",
                            seeds=tuple(int(s) for s in
                                        rng.choice(g.n, 2, replace=False)),
                            top_k=5)
                   for i in range(6)]
        for q in queries:
            svc.submit(q)
        results = svc.run_until_drained()
        assert svc.stats["solves"] == 1          # 6 queries, ONE batched call
        assert svc.stats["solved_queries"] == 6
        for q in queries:
            ref_idx, ref_scores = reference_topk(g, q.seeds, q.c, q.tol, q.top_k)
            r = results[q.qid]
            np.testing.assert_allclose(r.scores, ref_scores,
                                       rtol=1e-5, atol=1e-5)
            # compare as sets: near-ties may swap order between solves
            assert set(r.indices.tolist()) == set(ref_idx.tolist())

    def test_groups_split_by_operating_point(self):
        """Different (c, tol) queries cannot share a coefficient vector."""
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(3,), c=0.85))
        svc.submit(PPRQuery(qid=1, graph="g", seeds=(5,), c=0.5))
        svc.run_until_drained()
        assert svc.stats["solves"] == 2

    def test_batch_padding_buckets(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_batch=8)
        for i in range(3):  # 3 live queries pad to the 4-bucket
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i,)))
        svc.run_until_drained()
        assert svc.stats["padded_columns"] == 1


class TestCache:
    def test_cache_hit_skips_recomputation(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        q = PPRQuery(qid=0, graph="g", seeds=(7, 21), top_k=5)
        assert svc.submit(q) is None             # cold: queued
        first = svc.run_until_drained()[0]
        solves_before = svc.stats["solves"]

        hit = svc.submit(PPRQuery(qid=1, graph="g", seeds=(7, 21), top_k=5))
        assert hit is not None and hit.cached    # served at submit time
        assert svc.stats["solves"] == solves_before
        np.testing.assert_array_equal(hit.indices, first.indices)
        np.testing.assert_array_equal(hit.scores, first.scores)

    def test_seed_order_is_canonicalized(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(21, 7)))
        svc.run_until_drained()
        hit = svc.submit(PPRQuery(qid=1, graph="g", seeds=(7, 21)))
        assert hit is not None and hit.cached

    def test_lru_eviction(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, cache_capacity=2)
        for i in range(4):
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i,)))
        svc.run_until_drained()
        assert len(svc.cache) == 2
        assert svc.cache.evictions == 2
        # oldest entries are gone -> resolves again
        assert svc.submit(PPRQuery(qid=10, graph="g", seeds=(0,))) is None


class TestResultCacheIndex:
    """The per-graph key index behind O(entries-for-that-graph)
    invalidation: it must stay exactly in sync with the LRU dict through
    puts, updates, evictions and invalidations, and the hit/miss/eviction/
    invalidation counters must stay exact through the churn."""

    def _check_index(self, cache):
        from itertools import chain
        indexed = set(chain.from_iterable(cache._by_graph.values()))
        assert indexed == set(cache._d)
        for graph, keys in cache._by_graph.items():
            assert keys and all(k[0] == graph for k in keys)

    def test_counters_exact_through_churn(self):
        from repro.serve.result_cache import ResultCache
        cache = ResultCache(capacity=4)
        # 6 puts over 2 graphs -> 2 evictions (the 2 oldest "a" keys)
        for i in range(3):
            cache.put(("a", 0, (i,)), i)
        for i in range(3):
            cache.put(("b", 0, (i,)), i)
        self._check_index(cache)
        assert len(cache) == 4 and cache.evictions == 2
        assert cache.get(("a", 0, (0,))) is None        # evicted -> miss
        assert cache.get(("a", 0, (2,))) == 2           # survivor -> hit
        assert cache.get(("b", 0, (1,))) == 1
        assert (cache.hits, cache.misses) == (2, 1)
        # duplicate put must not double-index
        cache.put(("b", 0, (1,)), 99)
        self._check_index(cache)
        assert len(cache) == 4 and cache.get(("b", 0, (1,))) == 99
        # invalidation drops exactly graph-b entries, counts them, and
        # leaves graph-a untouched
        dropped = cache.invalidate_graph("b")
        assert dropped == 3 and cache.invalidations == 3
        self._check_index(cache)
        assert len(cache) == 1 and cache.get(("a", 0, (2,))) == 2
        # invalidating an absent graph is a counted no-op
        assert cache.invalidate_graph("nope") == 0
        assert cache.invalidations == 3
        assert cache.stats() == {"size": 1, "capacity": 4, "hits": 4,
                                 "misses": 1, "evictions": 2,
                                 "invalidations": 3, "retained": 0}

    def test_index_survives_eviction_of_a_graphs_last_key(self):
        from repro.serve.result_cache import ResultCache
        cache = ResultCache(capacity=1)
        cache.put(("a", 0, (1,)), 1)
        cache.put(("b", 0, (1,)), 2)    # evicts a's only key
        self._check_index(cache)
        assert "a" not in cache._by_graph
        assert cache.invalidate_graph("a") == 0

    def test_service_invalidation_uses_index(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        for i in range(5):
            svc.submit(PPRQuery(qid=i, graph="g", seeds=(i,)))
        svc.run_until_drained()
        assert len(svc.cache) == 5
        svc.update_graph("g", insert=[(0, 90)])
        assert len(svc.cache) == 0 and svc.cache.invalidations == 5
        assert svc.cache._by_graph == {}


class TestZeroColumnGuard:
    def test_zero_personalization_column_cannot_poison_the_cache(self):
        """An all-zero column reaching the batched solve (an empty or fully-
        filtered seed set) must come back as finite zeros — NOT NaNs that
        would be cached and served. Exercised through the service's own
        jitted solve paths (fixed and adaptive)."""
        import jax.numpy as jnp
        from repro.serve.pagerank_service import (_solve_topk,
                                                  _solve_topk_adaptive)
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        rg = svc.registry.get("g")
        sched, coeffs = svc.registry.schedule(0.85, 1e-4)
        p = np.zeros((g.n, 2), np.float32)
        p[7, 0] = 1.0                       # live query; column 1 all-zero
        idx, scores = _solve_topk(rg.engine, coeffs, jnp.asarray(p),
                                  rounds=sched.rounds, k=4)
        assert np.all(np.isfinite(np.asarray(scores)))
        np.testing.assert_array_equal(np.asarray(scores)[1], 0.0)
        plan = svc.registry.adaptive_schedule(0.85, 1e-4)
        idx_a, scores_a, used, _, _ = _solve_topk_adaptive(
            rg.engine, jnp.asarray(p), plan.c, plan.tol,
            max_rounds=plan.max_rounds, chunk=plan.chunk, k=4)
        assert np.all(np.isfinite(np.asarray(scores_a)))
        np.testing.assert_array_equal(np.asarray(scores_a)[1], 0.0)
        assert int(used) <= plan.max_rounds
        # the live column is unaffected by its dead neighbour
        ref_idx, ref_scores = reference_topk(g, (7,), 0.85, 1e-4, 4)
        np.testing.assert_allclose(np.asarray(scores)[0], ref_scores,
                                   rtol=1e-5, atol=1e-6)


class TestDynamicUpdates:
    def test_update_bumps_epoch_and_invalidates(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        q = PPRQuery(qid=0, graph="g", seeds=(5, 50), top_k=5)
        svc.submit(q)
        stale = svc.run_until_drained()[0]
        assert stale.epoch == 0

        # connect two far-apart vertices: PPR mass must move
        epoch = svc.update_graph("g", insert=[(5, 90)])
        assert epoch == 1
        assert svc.cache.invalidations == 1

        res = svc.submit(PPRQuery(qid=1, graph="g", seeds=(5, 50), top_k=5))
        assert res is None                       # stale result NOT served
        fresh = svc.run_until_drained()[1]
        assert fresh.epoch == 1 and not fresh.cached
        assert not np.allclose(fresh.scores, stale.scores, atol=1e-7)

        # the fresh answer matches a from-scratch solve on the updated graph
        g_new = svc.registry.get("g").host
        ref_idx, ref_scores = reference_topk(g_new, q.seeds, q.c, q.tol, 5)
        np.testing.assert_allclose(fresh.scores, ref_scores,
                                   rtol=1e-5, atol=1e-5)

    def test_insert_then_delete_roundtrips(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        keys0 = _undirected_keys(svc.registry.get("g").host)
        svc.update_graph("g", insert=[(0, 77)])
        keys1 = _undirected_keys(svc.registry.get("g").host)
        assert len(keys1) == len(keys0) + 1
        svc.update_graph("g", delete=[(77, 0)])  # orientation-insensitive
        keys2 = _undirected_keys(svc.registry.get("g").host)
        np.testing.assert_array_equal(keys2, keys0)
        assert svc.registry.get("g").epoch == 2

    def test_duplicate_insert_and_absent_delete_are_noops(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g)
        keys0 = _undirected_keys(g)
        u, v = int(g.src[0]), int(g.dst[0])
        svc.update_graph("g", insert=[(u, v)], delete=[(0, 98)])
        np.testing.assert_array_equal(
            _undirected_keys(svc.registry.get("g").host), keys0)


class TestTopK:
    def test_topk_agrees_with_dense_oracle(self):
        g = generators.tri_mesh(8, 9)
        svc = make_service(g, max_top_k=8)
        seeds = (3, 40)
        res = svc.query("g", seeds, tol=1e-8, top_k=8)

        p = np.zeros(g.n)
        p[list(seeds)] = 0.5
        oracle = true_pagerank_dense(g, 0.85, p=p)
        oracle_rank = np.argsort(-oracle, kind="stable")[:8]
        assert set(res.indices.tolist()) == set(oracle_rank.tolist())
        np.testing.assert_allclose(res.scores, oracle[res.indices],
                                   rtol=1e-4, atol=1e-6)
        # scores come back ranked
        assert np.all(np.diff(res.scores) <= 1e-12)

    def test_topk_truncation_per_query(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_top_k=8)
        r3 = svc.query("g", (4,), top_k=3)
        r8 = svc.query("g", (4,), top_k=8)
        assert len(r3.indices) == 3 and len(r8.indices) == 8
        np.testing.assert_array_equal(r3.indices, r8.indices[:3])


class TestValidation:
    def test_rejects_bad_queries(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_top_k=8)
        with pytest.raises(ValueError):
            svc.submit(PPRQuery(qid=0, graph="g", seeds=()))
        with pytest.raises(ValueError):
            svc.submit(PPRQuery(qid=1, graph="g", seeds=(g.n,)))
        with pytest.raises(ValueError):
            svc.submit(PPRQuery(qid=2, graph="g", seeds=(0,), top_k=9))
        with pytest.raises(KeyError):
            svc.submit(PPRQuery(qid=3, graph="nope", seeds=(0,)))

    def test_registry_rejects_duplicates_and_bad_edges(self):
        registry = GraphRegistry()
        g = generators.tri_mesh(5, 5)
        registry.register("g", g)
        with pytest.raises(ValueError):
            registry.register("g", g)
        with pytest.raises(ValueError):
            registry.apply_updates("g", insert=[(0, g.n)])
        with pytest.raises(ValueError):
            registry.apply_updates("g", insert=[(3, 3)])


class TestRetraceGate:
    """Steady-state serving must not recompile: the RetraceGate
    (repro.analysis.retrace) watches the engine trace-time apply log."""

    def test_steady_state_ticks_have_zero_recompiles(self, retrace_gate):
        g = generators.tri_mesh(13, 17)
        svc = make_service(g, max_batch=8)
        # Warm up: first solo query compiles the bucket-1 solve.
        svc.query("g", seeds=(0, 1), top_k=5)
        svc.query("g", seeds=(2, 3), top_k=5)
        solves_before = svc.stats["solves"]
        with retrace_gate():
            for i in range(20):
                svc.query("g", seeds=(4 + i, 30 + i), top_k=5)
        # The gate must have watched real solves, not cache hits.
        assert svc.stats["solves"] == solves_before + 20

    def test_gate_trips_on_batch_bucket_change(self, retrace_gate):
        from repro.analysis.retrace import RetraceError

        g = generators.tri_mesh(13, 17)
        svc = make_service(g, max_batch=8)
        svc.query("g", seeds=(0,), top_k=5)      # warm bucket 1 only
        with pytest.raises(RetraceError) as ei:
            with retrace_gate():
                # Two distinct-seed queries batch together -> bucket 2 ->
                # a fresh [n, 2] trace of the solve.
                svc.submit(PPRQuery(qid=100, graph="g", seeds=(1,), top_k=5))
                svc.submit(PPRQuery(qid=101, graph="g", seeds=(2,), top_k=5))
                svc.run_until_drained()
        msg = str(ei.value)
        assert "NEW signature" in msg        # shape drift, not pytree churn
        assert "warmup signatures" in msg    # the diff names both sides

    def test_gate_allowance_tolerates_expected_traces(self, retrace_gate):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, max_batch=8)
        svc.query("g", seeds=(0,), top_k=5)
        with retrace_gate(allowed=4):
            svc.submit(PPRQuery(qid=200, graph="g", seeds=(1,), top_k=5))
            svc.submit(PPRQuery(qid=201, graph="g", seeds=(2,), top_k=5))
            svc.run_until_drained()
