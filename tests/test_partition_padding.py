"""Regression tests for the partition padding-edge convention.

`partition_1d`/`partition_2d` pad every device's edge list to a rectangular
[D, E_pad] by pointing the filler edges at the LAST local row slot
(`rows - 1`, i.e. global slot n_pad - 1 of the chunk) with weight 0. When n
is exactly a multiple of D * lane there is NO padded vertex — the
sacrificial slot lands on a REAL vertex — so correctness rests entirely on
the zero weight (the slot receives `x[src_pad] * 0`). These tests pin that
contract: a real vertex occupying the sacrificial slot keeps exactly its
correct mass, for both partitions and through the full sharded solve.
"""
import numpy as np
import pytest

from repro.graph.partition import partition_1d, partition_2d
from repro.graph.structure import Graph


def _ring(n: int) -> Graph:
    """Cycle plus one chord: every vertex (including n-1, the sacrificial
    slot when n == n_pad) has mass, and the chord imbalances the per-device
    edge counts so the rectangular stacking actually emits padding edges."""
    u = np.arange(n, dtype=np.int64)
    return Graph.from_undirected_edges(
        n, np.concatenate([u, [0]]), np.concatenate([(u + 1) % n, [n // 2]]))


def _dense_p(g: Graph) -> np.ndarray:
    a = np.zeros((g.n, g.n))
    a[g.dst, g.src] = 1.0
    return a / np.maximum(a.sum(0), 1.0)[None, :]


def test_partition_1d_sacrificial_slot_keeps_mass():
    n_dev, lane = 4, 4
    g = _ring(n_dev * lane)              # n == D * lane -> n_pad == n exactly
    part = partition_1d(g, n_dev, lane=lane)
    assert part.n == g.n                 # no spare slot: rows-1 is real
    assert np.any(part.weight == 0)      # padding edges exist
    x = np.random.default_rng(0).random(g.n).astype(np.float64)
    y = np.zeros(part.n)
    rows = part.rows_per_dev
    for d in range(part.n_dev):
        np.add.at(y, d * rows + part.dst_local[d],
                  x[part.src[d]] * part.weight[d].astype(np.float64))
    expect = _dense_p(g) @ x
    np.testing.assert_allclose(y, expect, rtol=1e-6, atol=1e-9)
    # the sacrificial slot itself, explicitly
    np.testing.assert_allclose(y[g.n - 1], expect[g.n - 1], rtol=1e-6)


def test_partition_2d_sacrificial_slot_keeps_mass():
    grid, lane = (2, 2), 4
    g = _ring(grid[0] * grid[1] * lane)  # n == R * C * lane -> n_pad == n
    part = partition_2d(g, grid, lane=lane)
    assert part.n == g.n
    assert np.any(part.weight == 0)      # padding edges exist
    rows, sub = part.rows_per_chunk, part.sub
    # column-chunk view of x: x_col[c] stacks the nested sub-chunks
    x = np.random.default_rng(1).random(g.n).astype(np.float64)
    x_col = np.empty((grid[1], part.cols_per_chunk))
    for c in range(grid[1]):
        for r in range(grid[0]):
            x_col[c, r * sub:(r + 1) * sub] = \
                x[r * rows + c * sub: r * rows + (c + 1) * sub]
    y = np.zeros(part.n)
    for r in range(grid[0]):
        for c in range(grid[1]):
            np.add.at(y, r * rows + part.dst_local[r, c],
                      x_col[c][part.src_local[r, c]]
                      * part.weight[r, c].astype(np.float64))
    expect = _dense_p(g) @ x
    np.testing.assert_allclose(y, expect, rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(y[g.n - 1], expect[g.n - 1], rtol=1e-6)


@pytest.mark.parametrize("kind", ["1d", "2d"])
def test_sharded_solve_at_exact_padding_boundary(kind):
    """End-to-end: the sharded engines on a graph whose size hits the
    padding boundary exactly must match the dense oracle everywhere,
    including at vertex n-1."""
    import jax
    from repro.core import cpaa, true_pagerank_dense
    from repro.core.engine import (Sharded1DEngine, Sharded2DEngine,
                                   factor_grid)
    n_dev = jax.device_count()
    lane = 4
    if kind == "1d":
        g = _ring(n_dev * lane)
        eng = Sharded1DEngine.from_graph(g, lane=lane)
    else:
        r, c = factor_grid(n_dev)
        g = _ring(r * c * lane)
        eng = Sharded2DEngine.from_graph(g, grid=(r, c), lane=lane)
    assert eng.n_pad == g.n
    pi = np.asarray(cpaa(eng, 0.85, 1e-8).pi, np.float64)
    truth = true_pagerank_dense(g, 0.85)
    np.testing.assert_allclose(pi, truth, rtol=5e-5, atol=1e-9)
