"""Opt-in scale smoke: hub-tail vs COO at n = 2*10^5 through the dataset
cache, plus one scale_compare record end-to-end.

Marked `scale` and additionally gated on RUN_SCALE_TESTS=1 so the default
`pytest` invocation (tier-1) never pays the multi-second generation +
solve; the CI scale-smoke job opts in explicitly.
"""
import os

import pytest

pytestmark = [
    pytest.mark.scale,
    pytest.mark.skipif(os.environ.get("RUN_SCALE_TESTS") != "1",
                       reason="set RUN_SCALE_TESTS=1 to run scale smoke"),
]


def test_hub_tail_parity_at_200k():
    import jax.numpy as jnp
    from repro.core import make_schedule
    from repro.core.engine import CooEngine, HubTailEngine
    from repro.core.pagerank import cpaa_fixed
    from repro.graph.datasets import scale_dataset
    from repro.graph.ops import device_graph

    # default cache dir ($REPRO_DATASET_CACHE in CI) so the preprocessed
    # binary persists across runs via actions/cache
    g = scale_dataset("chunglu-200k")
    assert g.n == 200_000
    sched = make_schedule(0.85, 1e-6)
    coeffs = jnp.asarray(sched.coeffs, jnp.float32)
    p = jnp.full((g.n,), 1.0 / g.n, jnp.float32)
    ref, _ = cpaa_fixed(CooEngine(device_graph(g)), coeffs, p,
                        rounds=sched.rounds)
    for wdtype, bar in ((None, 1e-5), (jnp.bfloat16, 1e-3)):
        eng = HubTailEngine.from_graph(g, weight_dtype=wdtype)
        pi, _ = cpaa_fixed(eng, coeffs, p, rounds=sched.rounds)
        assert float(jnp.abs(pi - ref).sum()) <= bar, wdtype


def test_scale_compare_produces_records():
    from benchmarks.scale_bench import scale_compare

    rows, records = scale_compare(quick=True, families=("chunglu-200k",))
    assert len(rows) > 1   # header + data
    timed = [r for r in records if r["us_per_iter"] is not None]
    engines = {(r["engine"], r["weight_dtype"]) for r in timed}
    assert ("coo", "float32") in engines
    assert ("hub_tail", "bfloat16") in engines
    for r in timed:
        if r["engine"] != "coo" or r["weight_dtype"] != "float32":
            assert r["l1_vs_coo_f32"] <= 1e-3
    ht_bf16 = next(r for r in timed if r["engine"] == "hub_tail"
                   and r["weight_dtype"] == "bfloat16")
    # the packed split must actually shrink device residency
    assert ht_bf16["bytes_ratio_vs_coo_f32"] > 1.5
