"""Scheduling tier: solve-time estimator, admission control, EDF ordering,
hold/release decisions, the no-starvation property, and the service-level
behaviors that ride on them (rejection accounting, deadline misses,
async-dispatch parity, the refresh-tick foreground yield)."""
import math

import numpy as np
import pytest

from repro.graph import generators
from repro.serve import (AdmissionRejected, DeadlineScheduler, FifoScheduler,
                         GraphRegistry, PageRankService, PPRQuery,
                         QueueEntry, SolveTimeEstimator, TenantSpec)
from _hypothesis_compat import given, settings, st


def entry(qid=0, graph="g", deadline=math.inf, tenant="default",
          priority=1, t0=0.0, c=0.85, tol=1e-4):
    """A QueueEntry around a real PPRQuery (the scheduler never solves)."""
    q = PPRQuery(qid=qid, graph=graph, seeds=(0,), c=c, tol=tol)
    return QueueEntry(q=q, t0=t0, tr=None, deadline=deadline,
                      tenant=tenant, priority=priority)


class TestSolveTimeEstimator:
    def test_fallback_chain_bucket_graph_global_default(self):
        est = SolveTimeEstimator(default_s=7.0)
        assert est.estimate("g", 4) == 7.0            # nothing observed
        est.observe("g", 4, 2.0)
        assert est.estimate("g", 4) == 2.0            # exact (graph, bucket)
        assert est.estimate("g", 8) == 2.0            # graph fallback
        assert est.estimate("other", 1) == 2.0        # global fallback

    def test_ewma_math(self):
        est = SolveTimeEstimator(alpha=0.25)
        est.observe("g", 4, 1.0)
        est.observe("g", 4, 2.0)
        assert est.estimate("g", 4) == pytest.approx(1.0 + 0.25 * (2.0 - 1.0))

    def test_exact_bucket_wins_over_fallbacks(self):
        est = SolveTimeEstimator(alpha=1.0)
        est.observe("g", 4, 0.1)
        est.observe("g", 16, 5.0)     # shifts graph + global EWMAs
        assert est.estimate("g", 4) == 0.1

    def test_reset_forgets_everything(self):
        est = SolveTimeEstimator(default_s=0.0)
        est.observe("g", 4, 3.0)
        est.reset()
        assert est.estimate("g", 4) == 0.0
        assert est.snapshot() == {}

    def test_snapshot_is_a_copy(self):
        est = SolveTimeEstimator()
        est.observe("g", 4, 1.0)
        snap = est.snapshot()
        snap.clear()
        assert est.estimate("g", 4) == 1.0

    def test_alpha_validated(self):
        with pytest.raises(ValueError):
            SolveTimeEstimator(alpha=0.0)
        with pytest.raises(ValueError):
            SolveTimeEstimator(alpha=1.5)


class TestFifoScheduler:
    def test_head_group_packed_in_arrival_order(self):
        s = FifoScheduler(max_batch=8)
        s.admit(entry(0, c=0.85))
        s.admit(entry(1, c=0.5))      # different operating point
        s.admit(entry(2, c=0.85))
        group = s.next_group(now=0.0)
        assert [e.q.qid for e in group] == [0, 2]
        assert s.depth() == 1
        assert [e.q.qid for e in s.next_group(now=0.0)] == [1]

    def test_max_batch_caps_a_group(self):
        s = FifoScheduler(max_batch=2)
        for i in range(5):
            s.admit(entry(i))
        assert [e.q.qid for e in s.next_group(0.0)] == [0, 1]
        assert s.depth() == 3

    def test_never_holds(self):
        s = FifoScheduler(max_batch=8)
        s.admit(entry(0, deadline=math.inf))
        assert s.next_group(now=0.0, force=False) is not None

    def test_admission_bound(self):
        s = FifoScheduler(max_batch=8, max_depth=2)
        s.admit(entry(0))
        s.admit(entry(1))
        with pytest.raises(AdmissionRejected) as exc:
            s.admit(entry(2, tenant="t"))
        assert exc.value.reason == "queue_full"
        assert exc.value.tenant == "t"
        assert exc.value.depth == 2

    def test_drain_clears(self):
        s = FifoScheduler(max_batch=8)
        s.admit(entry(0))
        s.admit(entry(1))
        assert [e.q.qid for e in s.drain()] == [0, 1]
        assert s.depth() == 0
        assert s.next_group(0.0) is None


def dl_sched(max_batch=8, tenants=None, max_depth=None, margin=0.0,
             est=None, **kw):
    return DeadlineScheduler(
        max_batch, est if est is not None else SolveTimeEstimator(),
        tenants=tenants, max_depth=max_depth, slack_margin_s=margin, **kw)


class TestDeadlineAdmission:
    def test_per_tenant_bound_is_independent(self):
        s = dl_sched(tenants={"a": TenantSpec(name="a", max_depth=2)})
        s.admit(entry(0, tenant="a"))
        s.admit(entry(1, tenant="a"))
        with pytest.raises(AdmissionRejected) as exc:
            s.admit(entry(2, tenant="a"))
        assert (exc.value.reason, exc.value.tenant) == ("queue_full", "a")
        s.admit(entry(3, tenant="b"))     # other tenants unaffected
        assert s.depth_for("a") == 2 and s.depth_for("b") == 1

    def test_scheduler_wide_bound_is_the_fallback(self):
        s = dl_sched(max_depth=1)
        s.admit(entry(0, tenant="x"))
        with pytest.raises(AdmissionRejected):
            s.admit(entry(1, tenant="x"))
        # the bound is per tenant, not global
        s.admit(entry(2, tenant="y"))

    def test_depth_released_on_dispatch(self):
        s = dl_sched(max_depth=1)
        s.admit(entry(0, tenant="x", deadline=0.0))
        assert s.next_group(now=1.0) is not None
        assert s.depth_for("x") == 0
        s.admit(entry(1, tenant="x"))     # slot freed


class TestDeadlineRelease:
    def test_holds_while_slack_above_margin(self):
        s = dl_sched()
        s.admit(entry(0, deadline=10.0))
        assert s.next_group(now=0.0) is None          # slack 10 > 0: hold
        assert [e.q.qid for e in s.next_group(now=10.0)] == [0]

    def test_margin_releases_early(self):
        s = dl_sched(margin=3.0)
        s.admit(entry(0, deadline=10.0))
        assert s.next_group(now=6.0) is None          # slack 4 > margin 3
        assert s.next_group(now=7.0) is not None      # slack 3 <= margin

    def test_estimate_shifts_the_release_point(self):
        est = SolveTimeEstimator()
        est.observe("g", 1, 2.0)
        s = dl_sched(est=est)
        s.admit(entry(0, deadline=10.0))
        assert s.next_group(now=7.0) is None          # 10 - 7 - 2 = 1 > 0
        assert s.next_group(now=8.0) is not None      # slack 0

    def test_full_bucket_releases_regardless_of_slack(self):
        s = dl_sched(max_batch=2)
        s.admit(entry(0, deadline=math.inf))
        assert s.next_group(now=0.0) is None
        s.admit(entry(1, deadline=math.inf))
        assert len(s.next_group(now=0.0)) == 2

    def test_force_releases_unbounded_deadlines(self):
        """Regression: all-infinite-slack groups (no deadline anywhere)
        must still elect a candidate for the force path."""
        s = dl_sched()
        s.admit(entry(0, deadline=math.inf))
        assert s.next_group(now=0.0, force=False) is None
        assert [e.q.qid for e in s.next_group(now=0.0, force=True)] == [0]

    def test_edf_across_groups(self):
        s = dl_sched()
        s.admit(entry(0, graph="slow", deadline=20.0))
        s.admit(entry(1, graph="fast", deadline=5.0))
        group = s.next_group(now=30.0)                # both overdue
        assert [e.q.qid for e in group] == [1]        # earliest deadline

    def test_within_group_order_deadline_then_priority(self):
        s = dl_sched()
        s.admit(entry(0, deadline=9.0, priority=1))
        s.admit(entry(1, deadline=5.0, priority=1))
        s.admit(entry(2, deadline=5.0, priority=3))
        group = s.next_group(now=10.0)
        assert [e.q.qid for e in group] == [2, 1, 0]  # ties -> priority

    def test_tenants_share_a_device_batch(self):
        s = dl_sched()
        s.admit(entry(0, tenant="a", deadline=5.0))
        s.admit(entry(1, tenant="b", deadline=6.0))
        assert len(s.next_group(now=10.0)) == 2       # merged per group key

    def test_min_slack(self):
        est = SolveTimeEstimator()
        est.observe("g", 1, 1.0)
        s = dl_sched(est=est)
        assert s.min_slack(now=0.0) == math.inf
        s.admit(entry(0, deadline=10.0))
        assert s.min_slack(now=4.0) == pytest.approx(5.0)

    def test_drain_most_urgent_first_and_clears(self):
        s = dl_sched()
        s.admit(entry(0, graph="a", deadline=9.0))
        s.admit(entry(1, graph="b", deadline=3.0))
        assert [e.q.qid for e in s.drain()] == [1, 0]
        assert s.depth() == 0 and s.depth_for("default") == 0


class TestNoStarvationProperty:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(
        st.tuples(st.floats(0.0, 8.0, allow_nan=False),   # arrival time
                  st.floats(0.1, 5.0, allow_nan=False),   # latency budget
                  st.integers(0, 2)),                     # graph index
        min_size=1, max_size=30))
    def test_no_admitted_query_starved_past_deadline_plus_one_tick(
            self, arrivals):
        """Drive a synthetic clock in fixed ticks, draining every
        release-ready group per tick: with a cold estimator and zero
        margin, every admitted entry must dispatch by the first tick at or
        after its deadline — i.e. no later than deadline + one tick."""
        dt = 0.5
        s = dl_sched(max_batch=4)
        pending = sorted(((t, t + budget, f"g{gi}") for t, budget, gi
                          in arrivals), key=lambda e: e[0])
        deadlines, dispatched = {}, {}
        horizon = max(d for _, d, _ in pending) + 2 * dt
        qid, now = 0, 0.0
        while now <= horizon:
            while pending and pending[0][0] <= now:
                t, d, graph = pending.pop(0)
                s.admit(entry(qid, graph=graph, deadline=d, t0=t))
                deadlines[qid] = d
                qid += 1
            while True:                     # drain all release-ready groups
                group = s.next_group(now=now)
                if group is None:
                    break
                for e in group:
                    dispatched[e.q.qid] = now
            now += dt
        assert set(dispatched) == set(deadlines)
        for q, d in deadlines.items():
            assert dispatched[q] <= d + dt, \
                f"query {q} starved: deadline {d}, dispatched {dispatched[q]}"


# ---- service-level integration -------------------------------------------

def make_service(g, **kw):
    reg = GraphRegistry(update_mode=kw.pop("update_mode", "incremental"))
    reg.register("g", g)
    defaults = dict(max_batch=8, cache_capacity=64, max_top_k=8)
    defaults.update(kw)
    return PageRankService(reg, **defaults)


class TestServiceScheduling:
    def test_rejection_stays_outside_the_disposition_invariant(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, admission_depth=1)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(1,)))
        with pytest.raises(AdmissionRejected):
            svc.submit(PPRQuery(qid=1, graph="g", seeds=(2,)))
        st_ = svc.stats
        assert st_["queries"] == 1            # the reject was never accepted
        assert st_["rejected_queries"] == 1
        svc.run_until_drained()
        st_ = svc.stats
        assert st_["queries"] == (st_["cache_hits"] + st_["solved_queries"]
                                  + st_["dropped_queries"])

    def test_deadline_miss_counted_but_still_answered(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, scheduler="deadline")
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(1,), deadline_s=1e-9))
        results = svc.run_until_drained()
        assert 0 in results                   # missed, not dropped
        assert svc.stats["deadline_misses"] == 1
        assert svc.stats["solved_queries"] == 1

    def test_generous_deadline_never_misses(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, scheduler="deadline", default_deadline_s=60.0)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(1,)))
        svc.run_until_drained()
        assert svc.stats["deadline_misses"] == 0

    @pytest.mark.parametrize("scheduler", ["fifo", "deadline"])
    def test_async_dispatch_matches_sync_results(self, scheduler):
        g = generators.tri_mesh(13, 17)
        rng = np.random.default_rng(3)
        queries = [(tuple(int(s) for s in rng.choice(g.n, 2, replace=False)))
                   for _ in range(6)]

        def answers(async_dispatch):
            svc = make_service(g, max_batch=4, cache_capacity=0,
                               scheduler=scheduler,
                               async_dispatch=async_dispatch,
                               default_deadline_s=60.0)
            for i, seeds in enumerate(queries):
                svc.submit(PPRQuery(qid=i, graph="g", seeds=seeds, top_k=5))
            return svc.run_until_drained()

        sync, awaited = answers(False), answers(True)
        assert set(sync) == set(awaited)
        for qid in sync:
            np.testing.assert_allclose(awaited[qid].scores, sync[qid].scores,
                                       rtol=1e-5, atol=1e-6)

    def test_held_ticks_counted(self):
        g = generators.tri_mesh(9, 11)
        svc = make_service(g, scheduler="deadline", default_deadline_s=60.0)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(1,)))
        assert not svc.tick()                 # plenty of slack: held
        assert svc.pending() == 1             # still queued, not dropped
        assert svc.stats["held_ticks"] == 1
        svc.run_until_drained()               # force path still drains it
        assert svc.stats["solved_queries"] == 1

    def test_refresh_tick_yields_to_foreground_load(self):
        """Regression: the background refresh must defer while foreground
        queries are pending, and resume once the service is idle."""
        g = generators.tri_mesh(13, 17)
        svc = make_service(g, invalidation_radius=1, refresh_batch=4,
                           refresh_rounds=8)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(2,)))
        svc.run_until_drained()
        svc.update_graph("g", insert=[(0, 120)])
        assert len(svc._refresh) == 1         # near-boundary survivor queued
        svc.submit(PPRQuery(qid=1, graph="g", seeds=(40,)))   # foreground
        assert svc.refresh_tick() == 0        # yields: query is pending
        assert svc.stats["refresh_deferred"] == 1
        assert len(svc._refresh) == 1         # key stays put, not dropped
        while svc.pending():
            svc.tick(force=True)
        assert svc.refresh_tick() == 1        # idle again: refresh resumes
        assert svc.stats["refreshes"] == 1
