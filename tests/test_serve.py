"""Serving-engine tests: continuous batching, ragged decode, slot reuse."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small_lm():
    cfg = get("h2o-danube-1.8b").smoke_config()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_matches_reference_decode(small_lm):
    """Engine output for a single request == naive greedy decode."""
    cfg, params = small_lm
    prompt = np.array([3, 7, 1, 9, 4], np.int32)
    eng = ServeEngine(params, cfg, max_batch=4, max_len=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng.run_until_drained([req])
    assert req.done and len(req.out_tokens) >= 6

    # reference: repeated full forward, greedy
    toks = list(prompt)
    ref = []
    for _ in range(len(req.out_tokens)):
        logits, _ = tf.forward(params, jnp.asarray([toks]), cfg)
        nxt = int(jnp.argmax(logits[0, -1]))
        ref.append(nxt)
        toks.append(nxt)
    assert req.out_tokens == ref


def test_continuous_batching_ragged(small_lm):
    """Requests of different lengths decode together and all finish."""
    cfg, params = small_lm
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 3 + 2 * i).astype(np.int32),
                    max_new_tokens=4 + i) for i in range(5)]
    eng = ServeEngine(params, cfg, max_batch=2, max_len=64)  # forces queueing
    eng.run_until_drained(reqs)
    for r in reqs:
        assert r.done
        assert len(r.out_tokens) >= r.max_new_tokens


def test_batched_results_match_solo(small_lm):
    """A request decoded alongside others == the same request decoded alone."""
    cfg, params = small_lm
    p1 = np.array([5, 2, 8], np.int32)
    p2 = np.array([1, 1, 2, 3, 5, 8], np.int32)
    solo = Request(rid=0, prompt=p1, max_new_tokens=5)
    ServeEngine(params, cfg, max_batch=1, max_len=32).run_until_drained([solo])
    together_a = Request(rid=1, prompt=p1, max_new_tokens=5)
    together_b = Request(rid=2, prompt=p2, max_new_tokens=5)
    ServeEngine(params, cfg, max_batch=2, max_len=32).run_until_drained(
        [together_a, together_b])
    assert together_a.out_tokens == solo.out_tokens
