"""ShardedEngine parity suite: the mesh-sharded engines must produce the
same PageRank as the single-device COO engine and the dense oracle.

Covers 1D and 2D partitions, vector [n] and matrix [n, B] personalization,
1/2/8-device meshes (cases needing more devices than the process has SKIP —
CI's tests-multidevice job and the tier-1 subprocess wrapper run with 8
fake devices, a plain single-device run still exercises the 1-device mesh),
the 2D column-layout round-trip, the select_engine device heuristic, and
the serving registry over sharded engines.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core import (cpaa, cpaa_adaptive, cpaa_fixed, make_schedule,
                        true_pagerank_dense)
from repro.core.engine import (CooEngine, Sharded1DEngine, Sharded2DEngine,
                               factor_grid, select_engine)
from repro.graph import generators
from repro.graph.ops import device_graph

GRAPHS = {
    "mesh": lambda: generators.tri_mesh(9, 11),
    "powerlaw": lambda: generators.powerlaw_ba(120, 3, seed=2),
    "kmer": lambda: generators.kmer_chains(200, seed=4),
}
DEV_COUNTS = (1, 2, 8)


def _devices(n_dev):
    if jax.device_count() < n_dev:
        pytest.skip(f"needs {n_dev} devices, have {jax.device_count()}")
    return np.asarray(jax.devices()[:n_dev])


def _engine(kind: str, g, n_dev: int):
    if kind == "1d":
        mesh = Mesh(_devices(n_dev), ("dev",))
        return Sharded1DEngine.from_graph(g, mesh=mesh, lane=4)
    r, c = factor_grid(n_dev)
    mesh = Mesh(_devices(n_dev).reshape(r, c), ("row", "col"))
    return Sharded2DEngine.from_graph(g, mesh=mesh, grid=(r, c), lane=4)


class TestShardedParity:
    @pytest.mark.parametrize("n_dev", DEV_COUNTS)
    @pytest.mark.parametrize("kind", ["1d", "2d"])
    @pytest.mark.parametrize("gname", sorted(GRAPHS))
    def test_vector_matches_coo_and_oracle(self, gname, kind, n_dev):
        g = GRAPHS[gname]()
        eng = _engine(kind, g, n_dev)
        truth = true_pagerank_dense(g, 0.85)
        pi_coo = np.asarray(cpaa(CooEngine(device_graph(g)), 0.85, 1e-8).pi,
                            np.float64)
        pi = np.asarray(cpaa(eng, 0.85, 1e-8).pi, np.float64)
        assert pi.shape == (g.n,)
        assert np.abs(pi - pi_coo).sum() <= 1e-5          # L1 vs COO engine
        assert np.max(np.abs(pi - truth) / truth) < 5e-5  # vs dense oracle

    @pytest.mark.parametrize("n_dev", DEV_COUNTS)
    @pytest.mark.parametrize("kind", ["1d", "2d"])
    def test_batched_matches_coo(self, kind, n_dev):
        g = GRAPHS["mesh"]()
        eng = _engine(kind, g, n_dev)
        rng = np.random.default_rng(3)
        B = 4
        p = np.zeros((g.n, B), np.float32)
        for j in range(B):
            p[rng.choice(g.n, rng.integers(1, 4), replace=False), j] = 1.0
        pi_coo = np.asarray(cpaa(CooEngine(device_graph(g)), 0.85, 1e-8,
                                 p=jnp.asarray(p)).pi)
        pi = np.asarray(cpaa(eng, 0.85, 1e-8, p=jnp.asarray(p)).pi)
        assert pi.shape == (g.n, B)
        np.testing.assert_allclose(pi, pi_coo, rtol=1e-5, atol=1e-7)
        oracle = np.asarray(true_pagerank_dense(g, 0.85, p=p))
        np.testing.assert_allclose(pi, oracle, rtol=1e-4, atol=1e-7)

    def test_power_through_sharded(self):
        from repro.core import power
        g = GRAPHS["mesh"]()
        eng = _engine("1d", g, 1)
        a = np.asarray(power(eng, 0.85, tol=1e-12, max_iter=2000).pi)
        b = np.asarray(power(device_graph(g), 0.85, tol=1e-12,
                             max_iter=2000).pi)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-8)


class TestAdaptiveSharded:
    """Residual-controlled CPAA on the mesh-sharded engines: the residual
    reduction is a cross-shard psum (the solve vectors are global sharded
    arrays), so parity with the single-device adaptive solve — and the
    a-priori round cap — must hold on every mesh shape."""

    @pytest.mark.parametrize("n_dev", DEV_COUNTS)
    @pytest.mark.parametrize("kind", ["1d", "2d"])
    @pytest.mark.parametrize("gname", sorted(GRAPHS))
    def test_vector_matches_coo_and_cap(self, gname, kind, n_dev):
        g = GRAPHS[gname]()
        eng = _engine(kind, g, n_dev)
        res = cpaa_adaptive(eng, 0.85, 1e-8)
        ref = cpaa_adaptive(CooEngine(device_graph(g)), 0.85, 1e-8)
        pi = np.asarray(res.pi, np.float64)
        assert pi.shape == (g.n,)
        assert np.abs(pi - np.asarray(ref.pi, np.float64)).sum() <= 1e-5
        truth = true_pagerank_dense(g, 0.85)
        assert np.max(np.abs(pi - truth) / truth) < 5e-5
        assert res.iterations <= res.rounds_bound
        assert res.iterations == ref.iterations  # same exit round everywhere

    @pytest.mark.parametrize("n_dev", DEV_COUNTS)
    @pytest.mark.parametrize("kind", ["1d", "2d"])
    def test_batched_matches_coo_with_column_masks(self, kind, n_dev):
        g = GRAPHS["mesh"]()
        eng = _engine(kind, g, n_dev)
        rng = np.random.default_rng(5)
        B = 4
        p = np.zeros((g.n, B), np.float32)
        p[:, 0] = 1.0 / g.n             # broad column: converges early
        for j in range(1, B):
            p[rng.choice(g.n, rng.integers(1, 4), replace=False), j] = 1.0
        res = cpaa_adaptive(eng, 0.85, 1e-8, p=jnp.asarray(p))
        ref = cpaa_adaptive(CooEngine(device_graph(g)), 0.85, 1e-8,
                            p=jnp.asarray(p))
        np.testing.assert_allclose(np.asarray(res.pi), np.asarray(ref.pi),
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_array_equal(res.column_rounds, ref.column_rounds)
        assert int(res.column_rounds.max()) <= res.rounds_bound

    @pytest.mark.parametrize("kind", ["1d", "2d"])
    def test_distributed_builders_adaptive_mode(self, kind):
        """The historical array-passing builders accept adaptive=True and
        agree with the fixed-round builders at the same operating point."""
        from repro.core.distributed import (cpaa_distributed_1d,
                                            cpaa_distributed_2d,
                                            col_layout_perm,
                                            pad_personalization,
                                            put_partition_1d,
                                            put_partition_2d)
        from repro.graph.partition import partition_1d, partition_2d
        g = GRAPHS["mesh"]()
        n_dev = min(2, jax.device_count())
        sched = make_schedule(0.85, 1e-8)
        if kind == "1d":
            mesh = Mesh(_devices(n_dev), ("dev",))
            part = partition_1d(g, n_dev, lane=4)
            arrs = put_partition_1d(part, mesh, ("dev",))
            p = pad_personalization(np.full(g.n, 1.0 / g.n, np.float32),
                                    part.n)
            fn_a = cpaa_distributed_1d(mesh, ("dev",), part, sched,
                                       adaptive=True)
            fn_f = cpaa_distributed_1d(mesh, ("dev",), part, sched)
            pi_a = np.asarray(fn_a(p, *arrs), np.float64)[: g.n]
            pi_f = np.asarray(fn_f(p, *arrs), np.float64)[: g.n]
        else:
            r, c = factor_grid(n_dev)
            mesh = Mesh(_devices(n_dev).reshape(r, c), ("row", "col"))
            part = partition_2d(g, (r, c), lane=4)
            arrs = put_partition_2d(part, mesh, "row", "col")
            perm = col_layout_perm(part.n, part.grid)
            p_col = pad_personalization(
                np.full(g.n, 1.0 / g.n, np.float32), part.n)[perm]
            fn_a = cpaa_distributed_2d(mesh, "row", "col", part, sched,
                                       adaptive=True)
            fn_f = cpaa_distributed_2d(mesh, "row", "col", part, sched)
            pi_a = np.asarray(fn_a(p_col, *arrs), np.float64)
            pi_f = np.asarray(fn_f(p_col, *arrs), np.float64)
        assert np.abs(pi_a - pi_f).sum() <= 1e-5


class TestShardedLayout:
    @pytest.mark.parametrize("kind", ["1d", "2d"])
    def test_to_from_internal_is_identity(self, kind):
        g = GRAPHS["powerlaw"]()
        n_dev = min(2, jax.device_count())
        eng = _engine(kind, g, n_dev)
        assert eng.n == g.n and eng.n_pad >= g.n
        for shape in [(g.n,), (g.n, 5)]:
            x = jnp.asarray(np.random.default_rng(0).random(shape),
                            jnp.float32)
            np.testing.assert_array_equal(
                np.asarray(eng.from_internal(eng.to_internal(x))),
                np.asarray(x))

    @pytest.mark.parametrize("kind", ["1d", "2d"])
    def test_apply_matches_coo_spmv(self, kind):
        from repro.graph.ops import spmv
        g = GRAPHS["mesh"]()
        n_dev = min(2, jax.device_count())
        eng = _engine(kind, g, n_dev)
        x = jax.random.normal(jax.random.PRNGKey(2), (g.n,), jnp.float32)
        y = eng.from_internal(eng.apply(eng.to_internal(x)))
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(spmv(device_graph(g), x)),
                                   rtol=2e-4, atol=1e-5)

    def test_2d_hlo_uses_reduce_scatter(self):
        if jax.device_count() < 2:
            pytest.skip("collectives degenerate on one device")
        g = GRAPHS["mesh"]()
        eng = _engine("2d", g, min(8, jax.device_count()))
        sched = make_schedule(0.85, rounds=8)
        coeffs = jnp.asarray(sched.coeffs, jnp.float32)
        p = jnp.ones((g.n,), jnp.float32)
        txt = jax.jit(lambda e, c, x: cpaa_fixed(e, c, x, rounds=8)) \
            .lower(eng, coeffs, p).compile().as_text()
        assert "reduce-scatter" in txt


class TestShardedSelection:
    def test_forced_modes_and_dash_aliases(self):
        g = GRAPHS["mesh"]()
        assert select_engine(g, mode="sharded_1d", lane=4).name == "sharded_1d"
        assert select_engine(g, mode="sharded-1d", lane=4).name == "sharded_1d"
        assert select_engine(g, mode="sharded-2d", lane=4).name == "sharded_2d"

    def test_auto_stays_single_device_below_threshold(self):
        # test graphs are far below SHARDED_MIN_N: the single-device
        # fill-rate logic must be untouched even on a multi-device process
        assert select_engine(generators.tri_mesh(5, 5)).name == "coo"

    def test_auto_shards_large_graphs_on_multi_device(self):
        if jax.device_count() < 2:
            pytest.skip("needs >= 2 devices")
        g = GRAPHS["mesh"]()  # n = 99; lower the bar instead of building 64k
        picked = select_engine(g, sharded_min_n=16, lane=4)  # 99 >= 4 * 16
        expected = "sharded_2d" if jax.device_count() >= 4 else "sharded_1d"
        assert picked.name == expected

    def test_auto_picks_1d_between_bars(self):
        if jax.device_count() < 4:
            pytest.skip("needs >= 4 devices")
        g = GRAPHS["mesh"]()  # n = 99 >= thr but < 4 * thr -> 1D
        assert select_engine(g, sharded_min_n=50, lane=4).name == "sharded_1d"


class TestShardedServe:
    @pytest.mark.parametrize("mode", ["sharded-1d", "sharded-2d"])
    def test_service_answers_match_oracle(self, mode):
        from repro.serve import GraphRegistry, PageRankService
        g = generators.tri_mesh(8, 9)
        reg = GraphRegistry(engine=mode, partition_lane=4)
        reg.register("g", g)
        assert reg.get("g").engine.name == mode.replace("-", "_")
        svc = PageRankService(reg, max_batch=4, cache_capacity=16,
                              max_top_k=8)
        seeds = (3, 40)
        res = svc.query("g", seeds, tol=1e-8, top_k=8)
        p = np.zeros(g.n)
        p[list(seeds)] = 0.5
        oracle = true_pagerank_dense(g, 0.85, p=p)
        assert set(res.indices.tolist()) == \
            set(np.argsort(-oracle, kind="stable")[:8].tolist())
        np.testing.assert_allclose(res.scores, oracle[res.indices],
                                   rtol=1e-4, atol=1e-6)

    def test_epoch_bump_rebuilds_partition(self):
        from repro.serve import GraphRegistry
        g = generators.tri_mesh(9, 11)
        reg = GraphRegistry(engine="sharded-1d", partition_lane=4)
        rg = reg.register("g", g)
        eng0 = rg.engine
        reg.apply_updates("g", insert=[(0, 90)])
        assert rg.engine is not eng0 and rg.engine.name == "sharded_1d"
