"""Training-substrate tests: optimizer, checkpointing (fault tolerance),
gradient compression, data pipelines, end-to-end loss descent."""
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.train import checkpoint as ckpt
from repro.train import grad_compress as gcmp
from repro.train.data import (RecsysPipelineConfig, TokenPipelineConfig,
                              recsys_batch, token_batch)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.train_loop import make_train_step


class TestAdamW:
    def test_quadratic_convergence(self):
        params = {"w": jnp.array([5.0, -3.0])}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        state = adamw_init(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(grads, state, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_grad_clip(self):
        params = {"w": jnp.zeros((3,))}
        cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
        state = adamw_init(params, cfg)
        _, _, metrics = adamw_update({"w": jnp.full((3,), 1e6)}, state,
                                     params, cfg)
        assert metrics["grad_norm"] > 1e5  # reported norm is pre-clip

    def test_bf16_moments(self):
        params = {"w": jnp.ones((4,))}
        cfg = AdamWConfig(m_dtype="bfloat16", v_dtype="bfloat16")
        state = adamw_init(params, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16
        p2, s2, _ = adamw_update({"w": jnp.ones((4,))}, state, params, cfg)
        assert s2["m"]["w"].dtype == jnp.bfloat16
        assert bool(jnp.isfinite(p2["w"]).all())


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"params": {"w": jax.random.normal(k, (8, 4)),
                           "b": jnp.zeros((4,), jnp.bfloat16)},
                "step_arr": jnp.int32(7)}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt.save(tmp_path, 3, tree, metadata={"data_step": 3})
        restored, meta = ckpt.restore(tmp_path, tree)
        assert meta["data_step"] == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_prune(self, tmp_path):
        tree = self._tree()
        for s in (1, 5, 9, 12):
            ckpt.save(tmp_path, s, tree)
        assert ckpt.latest_step(tmp_path) == 12
        ckpt.prune(tmp_path, keep=2)
        assert ckpt.latest_step(tmp_path) == 12
        with pytest.raises(FileNotFoundError):
            ckpt.restore(tmp_path / "nope", tree)

    def test_crash_safety_partial_write_ignored(self, tmp_path):
        """A step dir without the completion flag is never 'latest'."""
        tree = self._tree()
        ckpt.save(tmp_path, 1, tree)
        fake = tmp_path / "step_000000002"
        fake.mkdir()
        (fake / "data.bin").write_bytes(b"garbage")  # no flag file
        assert ckpt.latest_step(tmp_path) == 1
        restored, _ = ckpt.restore(tmp_path, tree)

    def test_async_save(self, tmp_path):
        tree = self._tree()
        t = ckpt.save(tmp_path, 4, tree, async_=True)
        t.join(timeout=30)
        assert ckpt.latest_step(tmp_path) == 4

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore onto explicit (single-device) shardings — the elastic path."""
        tree = self._tree()
        ckpt.save(tmp_path, 2, tree)
        dev = jax.devices()[0]
        shardings = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
        restored, _ = ckpt.restore(tmp_path, tree, shardings=shardings)
        assert restored["params"]["w"].sharding == \
            jax.sharding.SingleDeviceSharding(dev)


class TestGradCompression:
    @given(st.integers(min_value=1, max_value=4096),
           st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_quantization_error_bounded(self, n, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        q, scale = gcmp.compress(g)
        err = jnp.abs(gcmp.decompress(q, scale) - g)
        assert float(err.max()) <= float(scale) * 0.5 + 1e-6

    def test_error_feedback_removes_bias(self):
        """Sum of EF-compressed gradients tracks the true sum (bias-free)."""
        key = jax.random.PRNGKey(0)
        err = jnp.zeros((256,))
        total_true = jnp.zeros((256,))
        total_hat = jnp.zeros((256,))
        for i in range(60):
            g = jax.random.normal(jax.random.fold_in(key, i), (256,)) * 1e-3
            g_hat, err = gcmp.ef_compress(g, err)
            total_true += g
            total_hat += g_hat
        resid = float(jnp.max(jnp.abs(total_true - (total_hat + err))))
        assert resid < 1e-5  # invariant: sum(g) == sum(g_hat) + err

    def test_tree_api(self):
        params = {"a": jnp.ones((8,)), "b": jnp.ones((3, 3))}
        err = gcmp.init_error_tree(params)
        g_hat, err2 = gcmp.ef_compress_tree(params, err)
        assert jax.tree.structure(g_hat) == jax.tree.structure(params)


class TestDataPipelines:
    def test_token_batch_deterministic_and_resumable(self):
        cfg = TokenPipelineConfig(vocab=1000, seq_len=16, global_batch=4, seed=3)
        a = token_batch(cfg, step=17)
        b = token_batch(cfg, step=17)  # "resume" at the same step
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        c = token_batch(cfg, step=18)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))
        assert int(a["tokens"].max()) < 1000

    def test_recsys_batch_ids_in_range(self):
        cfg = RecsysPipelineConfig(vocab_sizes=(50, 500, 5000), n_dense=13,
                                   bag_size=2, global_batch=8)
        b = recsys_batch(cfg, 0)
        ids = np.asarray(b["sparse_ids"])
        offsets = np.array([0, 50, 550])
        for f in range(3):
            assert (ids[:, f] >= offsets[f]).all()
            assert (ids[:, f] < offsets[f] + (50, 500, 5000)[f]).all()

    def test_graph_pipeline_fixed_shapes_not_required_but_masked(self):
        from repro.graph import generators
        from repro.train.data import GraphBatchPipeline
        g = generators.powerlaw_ba(300, 3, seed=1)
        feats = np.random.default_rng(0).normal(size=(300, 6)).astype(np.float32)
        targets = np.zeros((300, 2), np.float32)
        pipe = GraphBatchPipeline(g, feats, targets, batch_nodes=16,
                                  fanouts=(4, 3), seed=0)
        b1 = pipe.batch(0)
        b2 = pipe.batch(0)
        np.testing.assert_array_equal(np.asarray(b1["senders"]),
                                      np.asarray(b2["senders"]))
        assert float(b1["node_mask"].sum()) == 16.0


class TestEndToEnd:
    def test_loss_decreases_tiny_lm(self):
        from repro.configs import get
        from repro.models import transformer as tf
        from repro.train.data import TokenPipelineConfig, token_batch
        cfg = get("deepseek-7b").smoke_config()
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
        opt = adamw_init(params, opt_cfg)
        from functools import partial
        step = make_train_step(partial(tf.loss_fn, cfg=cfg), opt_cfg,
                               num_microbatches=2, donate=False)
        dcfg = TokenPipelineConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)
        losses = []
        for i in range(30):
            batch = token_batch(dcfg, i % 2)  # cycle 2 batches -> memorizable
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]

    def test_checkpoint_restart_bitexact(self, tmp_path):
        """Crash/restart: restore params+opt and replay the same data step ->
        identical weights afterward (fault-tolerance requirement)."""
        from repro.configs import get
        from repro.models import transformer as tf
        from functools import partial
        cfg = get("deepseek-7b").smoke_config()
        params = tf.init_params(jax.random.PRNGKey(1), cfg)
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = adamw_init(params, opt_cfg)
        step = make_train_step(partial(tf.loss_fn, cfg=cfg), opt_cfg,
                               num_microbatches=1, donate=False)
        dcfg = TokenPipelineConfig(vocab=cfg.vocab, seq_len=12, global_batch=4)
        # run 3 steps, checkpoint at 2
        for i in range(2):
            params, opt, _ = step(params, opt, token_batch(dcfg, i))
        ckpt.save(tmp_path, 2, {"params": params, "opt": opt},
                  metadata={"data_step": 2})
        params3, opt3, _ = step(params, opt, token_batch(dcfg, 2))
        # "crash" -> restore -> replay step 2
        restored, meta = ckpt.restore(tmp_path, {"params": params, "opt": opt})
        rp, ro = restored["params"], restored["opt"]
        rp3, ro3, _ = step(rp, ro, token_batch(dcfg, meta["data_step"]))
        for a, b in zip(jax.tree.leaves(params3), jax.tree.leaves(rp3)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
