"""Incremental edge-update path: delta computation, in-place device
patches, engine refresh parity, selective cache invalidation, no-op
detection, edgeless epochs, and the warm-started re-solve tick."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import cpaa, true_pagerank_dense
from repro.graph import generators
from repro.graph.ops import EdgeSlots, device_graph, patch_device_graph
from repro.graph.structure import Graph, edge_delta
from repro.serve import GraphRegistry, PageRankService, PPRQuery
from repro.serve.graph_registry import _undirected_keys


def mesh_non_edges(g, count, offset=13, start=0):
    """(i, i + offset) pairs that are NOT tri_mesh edges (mesh offsets are
    1, cols, cols+1; callers pass an offset that avoids all three)."""
    return [(start + i, start + i + offset) for i in range(count)]


def random_non_edges(g, count, seed=0):
    rng = np.random.default_rng(seed)
    have = set(zip(np.minimum(g.src, g.dst).tolist(),
                   np.maximum(g.src, g.dst).tolist()))
    out = []
    while len(out) < count:
        u, v = int(rng.integers(0, g.n)), int(rng.integers(0, g.n))
        e = (min(u, v), max(u, v))
        if u != v and e not in have:
            have.add(e)
            out.append(e)
    return out


def service(g, mode="incremental", engine="auto", **kw):
    reg = GraphRegistry(update_mode=mode, engine=engine)
    reg.register("g", g)
    defaults = dict(max_batch=8, cache_capacity=64, max_top_k=8)
    defaults.update(kw)
    return PageRankService(reg, **defaults)


class TestEdgeDelta:
    def test_effective_sets_and_touched(self):
        g = generators.tri_mesh(5, 7)
        keys = _undirected_keys(g)
        n = g.n
        present = keys[0]
        absent = 0 * n + 13
        d = edge_delta(n, keys, insert_keys=[present, absent],
                       delete_keys=[keys[1]])
        np.testing.assert_array_equal(d.inserted, [absent])
        np.testing.assert_array_equal(d.deleted, [keys[1]])
        assert not d.is_noop
        expect = {0, 13, int(keys[1] // n), int(keys[1] % n)}
        assert set(d.touched.tolist()) == expect

    def test_noop_batch(self):
        g = generators.tri_mesh(5, 7)
        keys = _undirected_keys(g)
        n = g.n
        # duplicate insert + absent delete + delete-then-reinsert: all no-op
        d = edge_delta(n, keys, insert_keys=[keys[0], keys[2]],
                       delete_keys=[keys[2], 0 * n + 13])
        assert d.is_noop
        assert d.touched.size == 0

    def test_empty_key_set(self):
        d = edge_delta(10, np.empty(0, np.int64), insert_keys=[13],
                       delete_keys=[27])
        np.testing.assert_array_equal(d.inserted, [13])
        assert d.deleted.size == 0


class TestDevicePatchRoundTrip:
    """Insert a batch then delete the same batch == original DeviceGraph
    bit-for-bit, through both patch strategies (index scatter for slivers,
    mirror re-upload for bigger batches)."""

    @pytest.mark.parametrize("batch_size", [1, 40])
    def test_bit_for_bit(self, batch_size):
        g = generators.tri_mesh(9, 11)
        es = EdgeSlots.from_graph(g, 1024)
        dg = es.to_device()
        orig = {k: np.asarray(getattr(dg, k)).copy()
                for k in ("src", "dst", "w", "inv_deg")}
        keys0 = es.ekeys.copy()
        ins = np.array([u * g.n + v
                        for u, v in mesh_non_edges(g, batch_size)], np.int64)
        d1 = edge_delta(g.n, es.ekeys, ins, ())
        assert d1.inserted.size == batch_size   # true non-edges
        patch_device_graph(dg, es.apply_delta(d1))
        d2 = edge_delta(g.n, es.ekeys, (), ins)
        patch_device_graph(dg, es.apply_delta(d2))
        for k, v in orig.items():
            np.testing.assert_array_equal(np.asarray(getattr(dg, k)), v,
                                          err_msg=k)
        np.testing.assert_array_equal(es.ekeys, keys0)

    def test_mirror_matches_device_graph_builder(self):
        g = generators.tri_mesh(9, 11)
        es = EdgeSlots.from_graph(g, 1024)
        dg = es.to_device()
        ref = device_graph(g, pad_edges_to=1024)
        for k in ("src", "dst", "w", "inv_deg"):
            np.testing.assert_array_equal(np.asarray(getattr(dg, k)),
                                          np.asarray(getattr(ref, k)),
                                          err_msg=k)

    def test_device_arrays_never_alias_the_mutable_mirror(self):
        """jax's CPU backend zero-copies aligned numpy arrays; the mirror
        mutates its buffers in place on every batch, so the device graph
        must always receive private copies (both at build and on the bulk
        re-upload patch path)."""
        g = generators.tri_mesh(9, 11)
        es = EdgeSlots.from_graph(g, 1024)
        dg = es.to_device()
        src0 = np.asarray(dg.src).copy()
        es.src[:] = -1
        np.testing.assert_array_equal(np.asarray(dg.src), src0)
        es.src[:len(g.src)] = g.src        # restore
        es.src[len(g.src):] = 0
        # upload path: a batch big enough to take the bulk re-upload
        ins = np.array([u * g.n + v
                        for u, v in mesh_non_edges(g, 40)], np.int64)
        p = es.apply_delta(edge_delta(g.n, es.ekeys, ins, ()))
        assert p.slots.size * 64 >= es.cap     # really the upload path
        patch_device_graph(dg, p)
        snap = {k: np.asarray(getattr(dg, k)).copy()
                for k in ("src", "dst", "w")}
        es.apply_delta(edge_delta(g.n, es.ekeys, (), ins))  # mutates mirror
        for k, v in snap.items():
            np.testing.assert_array_equal(np.asarray(getattr(dg, k)), v,
                                          err_msg=k)

    def test_overflow_returns_none_and_leaves_mirror_untouched(self):
        g = generators.tri_mesh(9, 11)
        es = EdgeSlots.from_graph(g, g.m)    # zero headroom
        keys0 = es.ekeys.copy()
        deg0 = es.deg.copy()
        d = edge_delta(g.n, es.ekeys,
                       [u * g.n + v for u, v in mesh_non_edges(g, 2)], ())
        assert es.apply_delta(d) is None
        np.testing.assert_array_equal(es.ekeys, keys0)
        np.testing.assert_array_equal(es.deg, deg0)


ENGINES = ["coo", "block_ell", "fused", "sharded-1d"]


class TestIncrementalVsRebuildParity:
    """The delta path must land on the same solve as a from-scratch rebuild
    (L1 <= 1e-6), per engine, including across a bucket-boundary crossing
    (which exercises the rebuild fallback mid-stream)."""

    def _churn(self, svc, batches):
        for i, b in enumerate(batches):
            svc.update_graph("g", insert=b)
            if i % 2 == 1:
                svc.update_graph("g", delete=b)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_parity_after_churn(self, engine):
        g = generators.tri_mesh(9, 11)
        batches = [mesh_non_edges(g, 3, offset=13, start=7 * i)
                   for i in range(4)]
        svc_inc = service(g, "incremental", engine)
        svc_reb = service(g, "rebuild", engine)
        self._churn(svc_inc, batches)
        self._churn(svc_reb, batches)
        rg_i = svc_inc.registry.get("g")
        rg_r = svc_reb.registry.get("g")
        assert svc_inc.stats["incremental_updates"] > 0
        np.testing.assert_array_equal(rg_i.keys, rg_r.keys)
        # solve parity through the live engines + against a fresh build
        p = np.zeros(g.n, np.float32)
        p[5] = 1.0
        pi_i = np.asarray(cpaa(rg_i.engine, tol=1e-8, p=jnp.asarray(p)).pi)
        pi_r = np.asarray(cpaa(rg_r.engine, tol=1e-8, p=jnp.asarray(p)).pi)
        g_fresh = Graph.from_undirected_edges(g.n, rg_i.keys // g.n,
                                              rg_i.keys % g.n)
        pi_f = np.asarray(cpaa(device_graph(g_fresh), tol=1e-8,
                               p=jnp.asarray(p)).pi)
        assert np.abs(pi_i - pi_f).sum() <= 1e-6
        assert np.abs(pi_r - pi_f).sum() <= 1e-6

    def test_bucket_boundary_crossing_falls_back_and_stays_correct(self):
        g2 = generators.tri_mesh(9, 11)
        svc2 = service(g2, "incremental", "coo", max_top_k=4)
        cap0 = svc2.registry.get("g").slots.cap
        # enough fresh edges that 2 slots each overflow the bucket headroom
        big = random_non_edges(g2, (cap0 - g2.m) // 2 + 8, seed=3)
        svc2.update_graph("g", insert=big)
        rg = svc2.registry.get("g")
        assert not rg.last_update_incremental      # fallback taken
        assert rg.slots.cap > cap0                 # bucket grew
        assert rg.epoch == 1
        # parity after the crossing
        keys = rg.keys
        g_fresh = Graph.from_undirected_edges(g2.n, keys // g2.n,
                                              keys % g2.n)
        p = np.zeros(g2.n, np.float32)
        p[3] = 1.0
        pi_a = np.asarray(cpaa(rg.engine, tol=1e-8, p=jnp.asarray(p)).pi)
        pi_b = np.asarray(cpaa(device_graph(g_fresh), tol=1e-8,
                               p=jnp.asarray(p)).pi)
        assert np.abs(pi_a - pi_b).sum() <= 1e-6
        # and the NEXT update is incremental again in the grown bucket
        svc2.update_graph("g", delete=big[:4])
        assert svc2.registry.get("g").last_update_incremental

    def test_block_ell_refresh_keeps_perm_for_local_delta(self):
        g = generators.tri_mesh(12, 12)
        svc = service(g, "incremental", "block_ell")
        rg = svc.registry.get("g")
        perm0 = np.asarray(rg.engine.perm).copy()
        svc.update_graph("g", insert=[(0, 20)])
        rg = svc.registry.get("g")
        assert rg.last_update_incremental
        np.testing.assert_array_equal(np.asarray(rg.engine.perm), perm0)

    def test_sharded_refresh_keeps_mesh(self):
        g = generators.tri_mesh(9, 11)
        svc = service(g, "incremental", "sharded-1d")
        rg = svc.registry.get("g")
        mesh0 = rg.engine.mesh
        svc.update_graph("g", insert=[(0, 20)])
        assert svc.registry.get("g").engine.mesh is mesh0


class TestNoopUpdates:
    def test_noop_skips_rebuild_epoch_and_cache_flush(self):
        g = generators.tri_mesh(9, 11)
        svc = service(g, "incremental", "coo")
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(50,)))
        svc.run_until_drained()
        assert len(svc.cache) == 1
        rg = svc.registry.get("g")
        engine0, dg0, epoch0 = rg.engine, rg.dg, rg.epoch
        u, v = int(g.src[0]), int(g.dst[0])
        ep = svc.update_graph("g", insert=[(u, v)], delete=[(0, 98)])
        rg = svc.registry.get("g")
        assert ep == epoch0 and rg.epoch == epoch0
        assert rg.engine is engine0 and rg.dg is dg0   # nothing rebuilt
        assert len(svc.cache) == 1                     # nothing flushed
        assert svc.stats["updates"] == 1               # still counted
        assert svc.stats["noop_updates"] == 1
        hit = svc.submit(PPRQuery(qid=1, graph="g", seeds=(50,)))
        assert hit is not None and hit.cached

    def test_noop_in_rebuild_mode_too(self):
        g = generators.tri_mesh(9, 11)
        svc = service(g, "rebuild", "coo")
        epoch0 = svc.registry.get("g").epoch
        svc.update_graph("g", delete=[(0, 98)])
        assert svc.registry.get("g").epoch == epoch0


class TestSeedCanonicalization:
    def test_duplicate_seeds_share_cache_and_solve(self):
        g = generators.tri_mesh(9, 11)
        svc = service(g)
        q = PPRQuery(qid=0, graph="g", seeds=(7, 7, 21, 7))
        assert q.seeds == (7, 21)          # canonical at construction
        svc.submit(q)
        first = svc.run_until_drained()[0]
        # a duplicated-seed twin hits the deduped entry...
        hit = svc.submit(PPRQuery(qid=1, graph="g", seeds=(21, 7, 21)))
        assert hit is not None and hit.cached
        np.testing.assert_array_equal(hit.scores, first.scores)
        # ...and the served scores are correct FOR THE DEDUPED seed set
        p = np.zeros(g.n)
        p[[7, 21]] = 0.5
        oracle = true_pagerank_dense(g, 0.85, p=p)
        r = svc.query("g", (7, 7, 21), tol=1e-8, top_k=5)
        np.testing.assert_allclose(r.scores, oracle[r.indices],
                                   rtol=1e-4, atol=1e-6)


class TestDeleteToEmpty:
    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("mode", ["incremental", "rebuild"])
    def test_delete_every_edge_then_reinsert(self, engine, mode):
        g = generators.tri_mesh(5, 7)
        svc = service(g, mode, engine, max_top_k=4)
        keys0 = _undirected_keys(g)
        edges = [(int(k // g.n), int(k % g.n)) for k in keys0]
        svc.update_graph("g", delete=edges)
        rg = svc.registry.get("g")
        assert rg.keys.size == 0
        # the edgeless epoch is well-defined: every vertex isolated (self
        # loop patch), P = I, so PPR mass stays on the seed
        r = svc.query("g", (3,), top_k=4)
        assert r.indices[0] == 3 and r.scores[0] == pytest.approx(1.0)
        assert np.all(np.isfinite(r.scores))
        # global solve on the edgeless graph is uniform
        pi = np.asarray(cpaa(rg.engine, tol=1e-6).pi)
        np.testing.assert_allclose(pi, 1.0 / g.n, atol=1e-6)
        # re-insert everything: back to the original graph
        svc.update_graph("g", insert=edges)
        rg = svc.registry.get("g")
        np.testing.assert_array_equal(rg.keys, keys0)
        p = np.zeros(g.n, np.float32)
        p[3] = 1.0
        pi_a = np.asarray(cpaa(rg.engine, tol=1e-8, p=jnp.asarray(p)).pi)
        pi_b = np.asarray(cpaa(device_graph(g), tol=1e-8,
                               p=jnp.asarray(p)).pi)
        assert np.abs(pi_a - pi_b).sum() <= 1e-6


class TestSelectiveInvalidation:
    def test_far_entries_survive_near_entries_drop(self):
        g = generators.tri_mesh(13, 17)
        svc = service(g, invalidation_radius=2, cache_capacity=64)
        far_seed, near_seed = 220, 1    # near vertex 0; 220 is rows away
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(near_seed,)))
        svc.submit(PPRQuery(qid=1, graph="g", seeds=(far_seed,)))
        svc.run_until_drained()
        ep = svc.update_graph("g", insert=[(0, 35)])
        assert svc.stats["cache_dropped"] == 1
        assert svc.stats["cache_retained"] == 1
        # retained entry answers at the NEW epoch without a solve
        solves = svc.stats["solves"]
        hit = svc.submit(PPRQuery(qid=2, graph="g", seeds=(far_seed,)))
        assert hit is not None and hit.cached and hit.epoch == ep
        assert svc.stats["solves"] == solves
        # dropped entry misses and re-solves
        assert svc.submit(PPRQuery(qid=3, graph="g",
                                   seeds=(near_seed,))) is None

    def test_blanket_default_unchanged(self):
        g = generators.tri_mesh(9, 11)
        svc = service(g)               # invalidation_radius=None
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(90,)))
        svc.run_until_drained()
        svc.update_graph("g", insert=[(0, 20)])
        assert len(svc.cache) == 0

    def test_retained_entry_accuracy_vs_fresh_solve(self):
        """The Grolmusz locality bet, measured: a retained far entry's
        scores stay within serving tolerance of a fresh solve on the
        updated graph."""
        g = generators.tri_mesh(13, 17)
        svc = service(g, invalidation_radius=2, cache_capacity=64)
        far = 212
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(far,), tol=1e-6))
        svc.run_until_drained()
        svc.update_graph("g", insert=[(0, 35)])
        key = ("g", 1, (far,), 0.85, 1e-6)
        idx, scores = svc.cache.get(key, count=False)
        g_new = svc.registry.get("g").host
        p = np.zeros(g_new.n)
        p[far] = 1.0
        oracle = true_pagerank_dense(g_new, 0.85, p=p)
        assert np.max(np.abs(scores - oracle[idx])) < 1e-4

    def test_index_consistency_after_selective(self):
        from itertools import chain
        g = generators.tri_mesh(9, 11)
        svc = service(g, invalidation_radius=1)
        for i, s in enumerate([(0,), (50,), (90,)]):
            svc.submit(PPRQuery(qid=i, graph="g", seeds=s))
        svc.run_until_drained()
        svc.update_graph("g", insert=[(0, 20)])
        cache = svc.cache
        indexed = set(chain.from_iterable(cache._by_graph.values()))
        assert indexed == set(cache._d)
        assert cache.stats()["retained"] == cache.retained > 0


class TestRefreshTick:
    def test_near_boundary_entry_refreshes_toward_oracle(self):
        g = generators.tri_mesh(13, 17)
        svc = service(g, invalidation_radius=1, refresh_batch=4,
                      refresh_rounds=30, cache_capacity=64)
        near_boundary = 2              # 2 hops from vertex 0
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(near_boundary,)))
        svc.run_until_drained()
        ep = svc.update_graph("g", insert=[(0, 120)])
        assert len(svc._refresh) == 1
        assert svc.refresh_tick() == 1
        assert svc.stats["refreshes"] == 1
        key = ("g", ep, (near_boundary,), 0.85, 1e-4)
        idx, scores = svc.cache.get(key, count=False)
        g_new = svc.registry.get("g").host
        p = np.zeros(g_new.n)
        p[near_boundary] = 1.0
        oracle = true_pagerank_dense(g_new, 0.85, p=p)
        assert np.max(np.abs(scores - oracle[idx])) < 1e-3

    def test_refresh_never_degrades_a_retained_entry(self):
        """The cached warm start is top-k TRUNCATED: on graphs where the
        top-k holds little mass, a fixed short refine pass would re-cache
        an answer orders of magnitude WORSE than the retained one. The
        round count must scale with the truncation gap so the refreshed
        entry is at least as close to the new-graph oracle."""
        g = generators.caveman(12, 10, seed=0)   # spread-out PPR mass
        svc = service(g, invalidation_radius=1, refresh_batch=4,
                      refresh_rounds=8, cache_capacity=64)
        seed_v = 25                              # clique 2
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(seed_v,)))
        svc.run_until_drained()
        # far-away insert (cliques 8/9) retains + queues the entry
        ep = svc.update_graph("g", insert=[(85, 95)])
        key = ("g", ep, (seed_v,), 0.85, 1e-4)
        assert svc.cache.get(key, count=False) is not None
        idx0, s0 = svc.cache.get(key, count=False)
        g_new = svc.registry.get("g").host
        p = np.zeros(g_new.n)
        p[seed_v] = 1.0
        oracle = true_pagerank_dense(g_new, 0.85, p=p)
        before = np.max(np.abs(s0 - oracle[idx0]))
        if len(svc._refresh):
            assert svc.refresh_tick() >= 1
            idx1, s1 = svc.cache.get(key, count=False)
            after = np.max(np.abs(s1 - oracle[idx1]))
            assert after <= max(before, 1e-4) + 1e-6

    def test_superseded_epoch_is_skipped(self):
        g = generators.tri_mesh(13, 17)
        svc = service(g, invalidation_radius=1, refresh_batch=4,
                      cache_capacity=64)
        svc.submit(PPRQuery(qid=0, graph="g", seeds=(2,)))
        svc.run_until_drained()
        svc.update_graph("g", insert=[(0, 120)])
        assert len(svc._refresh) == 1
        # a second update lands ON the entry's seed: the entry is dropped
        # and the queued refresh (stale epoch) must be skipped
        svc.update_graph("g", insert=[(2, 121)])
        assert svc.refresh_tick() == 0
        assert svc.stats["refreshes"] == 0


class TestUpdateChurnService:
    """Property-style end-to-end: random churn through the service keeps
    (a) the key set equal to a replayed rebuild registry and (b) answers
    equal to fresh solves."""

    def test_random_churn_equivalence(self):
        g = generators.tri_mesh(9, 11)
        svc_i = service(g, "incremental", "coo", max_top_k=4)
        svc_r = service(g, "rebuild", "coo", max_top_k=4)
        rng = np.random.default_rng(0)
        live = set()
        for step in range(12):
            if live and rng.random() < 0.4:
                k = min(len(live), int(rng.integers(1, 4)))
                batch = [live.pop() for _ in range(k)]
                for svc in (svc_i, svc_r):
                    svc.update_graph("g", delete=batch)
            else:
                batch = []
                while len(batch) < 3:
                    u, v = int(rng.integers(0, g.n)), int(rng.integers(0, g.n))
                    if u != v:
                        batch.append((min(u, v), max(u, v)))
                live.update(batch)
                for svc in (svc_i, svc_r):
                    svc.update_graph("g", insert=batch)
            ki = svc_i.registry.get("g").keys
            kr = svc_r.registry.get("g").keys
            np.testing.assert_array_equal(ki, kr)
        # end-state answers agree with a dense oracle on the final graph
        g_end = svc_i.registry.get("g").host
        seeds = (5, 50)
        ri = svc_i.query("g", seeds, tol=1e-8, top_k=4)
        rr = svc_r.query("g", seeds, tol=1e-8, top_k=4)
        p = np.zeros(g_end.n)
        p[list(seeds)] = 0.5
        oracle = true_pagerank_dense(g_end, 0.85, p=p)
        for r in (ri, rr):
            np.testing.assert_allclose(r.scores, oracle[r.indices],
                                       rtol=1e-4, atol=1e-6)
